//! Byzantine behaviours for fault-injection tests: actors that *actively
//! misbehave* at the protocol level (beyond the crash/partition/torn-write
//! faults the simulator injects).
//!
//! The flagship attack is equivocation (§2.2): [`EquivocatingBroadcaster`]
//! crafts raw TBcast frames carrying *different* LOCK/LOCKED/SIGNED
//! payloads to different receivers for the same CTBcast identifier —
//! exactly what CTBcast (Alg 1) must neutralize.

use crate::consensus::msgs::{direct_frame, parse_direct, DirectMsg};
use crate::consensus::Replica;
use crate::crypto::{hash, KeyStore};
use crate::ctbcast::{signed_bytes, CtbMsg};
use crate::env::{Actor, Env, Event};
use crate::tbcast::TAG_TB;
use crate::util::wire::{Wire, WireWriter};
use crate::NodeId;

/// Craft a raw TBcast frame from scratch (bypassing `TbEndpoint`), as a
/// Byzantine process would: `ack=0, low=1`, a single `(seq, payload)`.
pub fn raw_tb_frame(seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(TAG_TB);
    w.u64(0); // ack
    w.u64(1); // low
    w.u32(1);
    w.u64(seq);
    w.bytes(payload);
    w.finish()
}

/// A Byzantine CTBcast broadcaster that sends message `m_a` to one set of
/// receivers and `m_b` to the rest, for the same identifier k — on both
/// the fast path (LOCK + LOCKED) and the slow path (SIGNED, with valid
/// signatures for both messages: Byzantine processes can sign anything).
pub struct EquivocatingBroadcaster {
    pub me: NodeId,
    pub ks: KeyStore,
    /// Receivers of the `a` story / the `b` story.
    pub recv_a: Vec<NodeId>,
    pub recv_b: Vec<NodeId>,
    pub m_a: Vec<u8>,
    pub m_b: Vec<u8>,
    /// Also run the slow path (send SIGNED)?
    pub slow: bool,
    seq: u64,
}

impl EquivocatingBroadcaster {
    pub fn new(
        me: NodeId,
        ks: KeyStore,
        recv_a: Vec<NodeId>,
        recv_b: Vec<NodeId>,
        m_a: Vec<u8>,
        m_b: Vec<u8>,
        slow: bool,
    ) -> Self {
        EquivocatingBroadcaster { me, ks, recv_a, recv_b, m_a, m_b, slow, seq: 0 }
    }

    fn send_story(&mut self, env: &mut dyn Env, k: u64, m: Vec<u8>, dsts: &[NodeId]) {
        // LOCK on my stream.
        self.seq += 1;
        let lock = CtbMsg::Lock { bcaster: self.me as u64, k, m: m.clone() }.encode();
        let f1 = raw_tb_frame(self.seq, &lock);
        // My LOCKED endorsement (I pretend to have committed to this m).
        self.seq += 1;
        let locked = CtbMsg::Locked { bcaster: self.me as u64, k, m: m.clone() }.encode();
        let f2 = raw_tb_frame(self.seq, &locked);
        for &d in dsts {
            env.send(d, f1.clone());
            env.send(d, f2.clone());
        }
        if self.slow {
            self.seq += 1;
            let h = hash(&m);
            let sig = self.ks.sign(self.me, &signed_bytes(self.me, k, &h));
            let signed = CtbMsg::Signed { bcaster: self.me as u64, k, m, sig }.encode();
            let f3 = raw_tb_frame(self.seq, &signed);
            for &d in dsts {
                env.send(d, f3.clone());
            }
        }
    }
}

impl Actor for EquivocatingBroadcaster {
    fn on_start(&mut self, env: &mut dyn Env) {
        let (m_a, m_b) = (self.m_a.clone(), self.m_b.clone());
        let (ra, rb) = (self.recv_a.clone(), self.recv_b.clone());
        self.send_story(env, 1, m_a, &ra);
        // Reset seq so the "b" story uses the same stream positions —
        // maximal equivocation (receivers see a consistent-looking
        // stream individually).
        self.seq = 0;
        self.send_story(env, 1, m_b, &rb);
    }
    fn on_event(&mut self, _env: &mut dyn Env, _ev: Event) {
        // Stays silent afterwards (drops all acks/retransmissions).
    }
}

/// A colluding replica for the stale-read attack on the direct read
/// lane: it participates in consensus *correctly* (wrapping a real
/// [`Replica`], so writes keep completing and it may even be part of
/// their response quorum), but answers every read-lane request with a
/// fixed stale payload and forged freshness claims.
///
/// By default it claims *maximal* freshness
/// (`applied_upto = decided_upto = u64::MAX`, sailing past any naive
/// freshness filter): together with one correct-but-lagging replica
/// this forms f+1 *matching* stale `ReadReply`s — exactly the quorum
/// [`crate::smr::ReadMode::Direct`] accepts and
/// [`crate::smr::ReadMode::Linearizable`] rejects (the lagging
/// partner's honest `applied_upto` fails the read-index check, and the
/// liar alone is short of a quorum).
///
/// [`StaleReadReplier::with_claims`] turns it into the *bound-deflating*
/// colluder instead: claiming a low `applied_upto`/`decided_upto` drags
/// the f+1-vouched read index down toward the session floor, so a
/// fresh-session reader paired with an honest replica stuck at that
/// level still completes a stale linearizable read — the documented
/// f+1-quorum fast-read trade-off ([`crate::rpc`] module docs). The
/// session floor is out of its reach: a client that completed writes
/// demands an index the deflated claims can never satisfy.
pub struct StaleReadReplier {
    inner: Replica,
    stale: Vec<u8>,
    applied_claim: u64,
    decided_claim: u64,
}

impl StaleReadReplier {
    pub fn new(inner: Replica, stale: Vec<u8>) -> StaleReadReplier {
        StaleReadReplier {
            inner,
            stale,
            applied_claim: u64::MAX,
            decided_claim: u64::MAX,
        }
    }

    /// Claim fixed `applied_upto` / `decided_upto` bounds instead of
    /// maximal freshness (the bound-deflating colluder).
    pub fn with_claims(mut self, applied: u64, decided: u64) -> StaleReadReplier {
        self.applied_claim = applied;
        self.decided_claim = decided;
        self
    }
}

impl Actor for StaleReadReplier {
    fn on_start(&mut self, env: &mut dyn Env) {
        self.inner.on_start(env);
    }

    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        if let Event::Recv { bytes, .. } = &ev {
            if let Some(DirectMsg::ReadRequest { req, .. }) = parse_direct(bytes) {
                let reply = DirectMsg::ReadReply {
                    rid: req.rid,
                    applied_upto: self.applied_claim,
                    decided_upto: self.decided_claim,
                    payload: self.stale.clone(),
                };
                env.send(req.client as NodeId, direct_frame(&reply));
                return; // the honest inner replica never sees the read
            }
        }
        self.inner.on_event(env, ev);
    }
}

/// A colluding replica for the forged-slot attack on the client's
/// session write bound: it runs consensus correctly (wrapping a real
/// [`Replica`]) but answers every read-lane request with a forged
/// *consensus-lane* `Response { slot: huge }` carrying `payload`. If
/// the payload matches what honest replicas serve, the forged reply
/// lands in their digest bucket — and a client that trusted a read
/// quorum's slots would jump its `written_upto` to the absurd slot,
/// demanding an unreachable read index from then on and wedging every
/// later linearizable read. The fix: only completed *writes* (whose
/// quorum always contains an honest slot-bearing reply) advance the
/// session write bound.
pub struct ForgedSlotReplier {
    inner: Replica,
    payload: Vec<u8>,
    slot: u64,
}

impl ForgedSlotReplier {
    pub fn new(inner: Replica, payload: Vec<u8>, slot: u64) -> ForgedSlotReplier {
        ForgedSlotReplier { inner, payload, slot }
    }
}

impl Actor for ForgedSlotReplier {
    fn on_start(&mut self, env: &mut dyn Env) {
        self.inner.on_start(env);
    }

    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        if let Event::Recv { bytes, .. } = &ev {
            if let Some(DirectMsg::ReadRequest { req, .. }) = parse_direct(bytes) {
                let reply = DirectMsg::Response {
                    rid: req.rid,
                    slot: self.slot,
                    payload: self.payload.clone(),
                };
                env.send(req.client as NodeId, direct_frame(&reply));
                return; // the honest inner replica never sees the read
            }
        }
        self.inner.on_event(env, ev);
    }
}

/// A broadcaster that writes garbage into its disaggregated-memory
/// registers (bogus checksums) to attack the slow path's liveness.
pub struct GarbageRegisterWriter {
    pub me: NodeId,
    pub reg: u32,
    pub mem_nodes: usize,
}

impl Actor for GarbageRegisterWriter {
    fn on_start(&mut self, env: &mut dyn Env) {
        for node in 0..self.mem_nodes {
            for sub in 0..2u32 {
                env.mem_write(
                    node,
                    crate::env::RegionId { owner: self.me, reg: self.reg * 2 + sub },
                    vec![0xAB; 48],
                );
            }
        }
    }
    fn on_event(&mut self, _env: &mut dyn Env, _ev: Event) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_frame_parses_like_a_real_one() {
        let payload = CtbMsg::App(b"x".to_vec()).encode();
        let frame = raw_tb_frame(3, &payload);
        let mut tb = crate::tbcast::TbEndpoint::new(1, vec![0, 1], 4);
        // low=1 with seq 3 leaves a gap at 1,2 — nothing delivered yet.
        let mut all = tb.on_frame(0, &frame);
        assert!(all.is_empty());
        // Frames for 1 and 2 complete the prefix.
        let f1 = raw_tb_frame(1, &payload);
        let f2 = raw_tb_frame(2, &payload);
        all.extend(tb.on_frame(0, &f1));
        all.extend(tb.on_frame(0, &f2));
        assert_eq!(all.len(), 3);
        assert_eq!(all.iter().map(|d| d.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
    }
}
