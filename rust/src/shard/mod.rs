//! Sharded multi-group uBFT: keyspace partitioning, per-shard consensus
//! groups, and two-phase cross-shard transactions.
//!
//! A single uBFT group decides in ~10 µs, but one leader's proposal rate
//! caps aggregate throughput. This module turns one [`Deployment`]
//! (`.shards(N, partitioner)`) into `N` *independent* 2f+1 consensus
//! groups, each owning a slice of the keyspace:
//!
//! * [`Partitioner`] maps a key to its home shard (default:
//!   [`HashPartitioner`]); closures `Fn(&[u8], usize) -> usize` work too.
//! * [`ShardRouter`] extracts a request's keys via [`Service::keys`] and
//!   steers it — writes *and* direct/linearizable reads — to the home
//!   group.
//! * [`ShardedReplica`]/`ShardEnv` host an unmodified consensus
//!   [`Replica`] at a global actor id by translating node ids at the
//!   environment boundary (peer sends, SWMR register owners, incoming
//!   message sources), so `N·n` replicas share one simulator.
//! * [`TxService`] wraps the application [`Service`] on every replica
//!   with a two-phase-commit participant: `Prepare` validates + locks a
//!   transaction's keys, `Commit`/`Abort` apply or discard the staged
//!   ops. All three travel through the shard's consensus as ordinary
//!   requests, so participant state is replicated, deterministic, and
//!   checkpointable. Staged locks carry a *lease*
//!   ([`crate::config::Config::tx_lease_ns`]): when a coordinator
//!   crashes between prepare and decision, the participants themselves
//!   emit an abort through their shard's consensus once the lease
//!   expires, so no lock outlives a dead coordinator.
//! * [`Coordinator`] is the client-side state machine: prepare on every
//!   touched shard, commit iff all vote commit, abort on any abort vote
//!   or prepare timeout.
//!
//! Consistency model: single-key operations remain linearizable within
//! their home shard (each shard is a full uBFT group, including the
//! direct/linearizable read lanes). Cross-shard transactions are atomic
//! and serializable via strict two-phase locking: while a key is locked
//! by an in-flight transaction, conflicting plain operations are
//! rejected with a deterministic [`TX_LOCKED`] reply and conflicting
//! transactions vote abort.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use crate::config::Config;
use crate::consensus::Replica;
use crate::crypto::{hash, hash_parts, Hash32};
use crate::deploy::{ActorSink, Deployment, SystemSpawner};
use crate::env::{Actor, Env, Event, RegionId, Ticket};
use crate::metrics::Category;
use crate::smr::{Checkpointable, Operation, Service};
use crate::util::wire::{get_list, get_map, put_list, put_map, WireReader, WireWriter};
use crate::util::Rng;
use crate::{Nanos, NodeId};

// ---------------------------------------------------------------------
// Partitioning
// ---------------------------------------------------------------------

/// Maps a key to its home shard. Implementations must be *stable*
/// (deterministic for a given `(key, shards)`) and *total* (every key
/// maps to exactly one shard in `0..shards`) — the router and every
/// replica rely on agreeing about key homes.
pub trait Partitioner: Send + Sync {
    fn shard_of(&self, key: &[u8], shards: usize) -> usize;
}

/// Any `Fn(&[u8], usize) -> usize` closure partitions; handy for tests
/// that pin specific keys to specific shards.
impl<F> Partitioner for F
where
    F: Fn(&[u8], usize) -> usize + Send + Sync,
{
    fn shard_of(&self, key: &[u8], shards: usize) -> usize {
        self(key, shards)
    }
}

/// Default partitioner: first 8 bytes of the key's BLAKE-style digest,
/// reduced mod `shards`. Uniform for any key distribution.
pub struct HashPartitioner;

impl Partitioner for HashPartitioner {
    fn shard_of(&self, key: &[u8], shards: usize) -> usize {
        if shards <= 1 {
            return 0;
        }
        let h = hash(key);
        let mut b = [0u8; 8];
        b.copy_from_slice(&h.0[..8]);
        (u64::from_le_bytes(b) % shards as u64) as usize
    }
}

// ---------------------------------------------------------------------
// Wire formats
// ---------------------------------------------------------------------

/// First byte of a client-side cross-shard transaction request: a list
/// of single-shard ops, each routed to its home group.
pub const TAG_TX: u8 = 0xF6;

/// First byte of a 2PC participant control request (prepare / commit /
/// abort) and of every participant reply.
pub const TAG_CTL: u8 = 0xF7;

/// Participant replies (second byte after [`TAG_CTL`]).
pub const TX_VOTE_ABORT: u8 = 0;
pub const TX_VOTE_COMMIT: u8 = 1;
pub const TX_COMMITTED: u8 = 2;
pub const TX_ABORTED: u8 = 3;
/// A plain (non-transactional) op touched a key locked by an in-flight
/// transaction and was rejected deterministically (strict 2PL).
pub const TX_LOCKED: u8 = 4;
/// A decision arrived for a transaction this participant no longer (or
/// never) had staged.
pub const TX_STALE: u8 = 5;

const CTL_PREPARE: u8 = 1;
const CTL_COMMIT: u8 = 2;
const CTL_ABORT: u8 = 3;

/// Encode a client transaction over `ops` (each op is a normal
/// application request owned by exactly one shard).
pub fn tx_request(ops: &[Vec<u8>]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(TAG_TX);
    put_list(&mut w, ops);
    w.finish()
}

/// Decode a [`tx_request`]; `None` if `req` is not a transaction.
pub fn parse_tx_request(req: &[u8]) -> Option<Vec<Vec<u8>>> {
    if req.first() != Some(&TAG_TX) {
        return None;
    }
    let mut r = WireReader::new(&req[1..]);
    let ops: Vec<Vec<u8>> = get_list(&mut r).ok()?;
    r.done().ok()?;
    if ops.is_empty() {
        return None;
    }
    Some(ops)
}

/// A participant control operation, decided through the shard's
/// consensus like any other request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Ctl {
    Prepare { txid: u64, ops: Vec<Vec<u8>> },
    Commit { txid: u64 },
    Abort { txid: u64 },
}

pub fn prepare_request(txid: u64, ops: &[Vec<u8>]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(TAG_CTL);
    w.u8(CTL_PREPARE);
    w.u64(txid);
    put_list(&mut w, ops);
    w.finish()
}

pub fn commit_request(txid: u64) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(TAG_CTL);
    w.u8(CTL_COMMIT);
    w.u64(txid);
    w.finish()
}

pub fn abort_request(txid: u64) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(TAG_CTL);
    w.u8(CTL_ABORT);
    w.u64(txid);
    w.finish()
}

/// Decode a participant control request; `None` if `req` is not one.
pub fn parse_ctl(req: &[u8]) -> Option<Ctl> {
    if req.len() < 2 || req[0] != TAG_CTL {
        return None;
    }
    let mut r = WireReader::new(&req[2..]);
    let ctl = match req[1] {
        CTL_PREPARE => Ctl::Prepare { txid: r.u64().ok()?, ops: get_list(&mut r).ok()? },
        CTL_COMMIT => Ctl::Commit { txid: r.u64().ok()? },
        CTL_ABORT => Ctl::Abort { txid: r.u64().ok()? },
        _ => return None,
    };
    r.done().ok()?;
    Some(ctl)
}

/// The deterministic reply for a plain op rejected by a lock.
pub fn locked_reply() -> Vec<u8> {
    vec![TAG_CTL, TX_LOCKED]
}

/// Did this reply come from the lock-rejection path?
pub fn is_locked(reply: &[u8]) -> bool {
    reply == [TAG_CTL, TX_LOCKED]
}

fn committed_reply(results: &[Vec<u8>]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(TAG_CTL);
    w.u8(TX_COMMITTED);
    put_list(&mut w, results);
    w.finish()
}

/// Decode the per-op results out of a [`TX_COMMITTED`] reply (either a
/// participant's or the coordinator's combined response).
pub fn parse_committed(reply: &[u8]) -> Option<Vec<Vec<u8>>> {
    if reply.len() < 2 || reply[0] != TAG_CTL || reply[1] != TX_COMMITTED {
        return None;
    }
    let mut r = WireReader::new(&reply[2..]);
    let results = get_list(&mut r).ok()?;
    r.done().ok()?;
    Some(results)
}

// ---------------------------------------------------------------------
// TxService: the replicated 2PC participant
// ---------------------------------------------------------------------

/// Bounded history of aborted/finished transaction ids. A tombstoned
/// txid votes abort on any late `Prepare`, which is what makes the
/// coordinator's timeout-abort safe: once `Abort` is decided on a
/// shard, a still-in-flight `Prepare` for the same transaction can
/// never resurrect its locks.
const TOMBSTONE_CAP: usize = 4096;

/// Wraps an application [`Service`] with a replicated two-phase-commit
/// participant. All state (lock table, staged ops, tombstones) mutates
/// only through `execute`, i.e. through the shard's consensus, so every
/// replica of the group holds the same participant state and it is
/// covered by checkpoints like any other application state.
pub struct TxService {
    inner: Box<dyn Service>,
    /// key -> txid holding its lock.
    locks: BTreeMap<Vec<u8>, u64>,
    /// txid -> ops staged at prepare, applied at commit.
    staged: BTreeMap<u64, Vec<Vec<u8>>>,
    tombstones: VecDeque<u64>,
    tombstoned: BTreeSet<u64>,
    /// Participant-side lock lease ([`crate::config::Config::tx_lease_ns`];
    /// 0 disables): a staged transaction whose decision hasn't arrived
    /// within the lease is aborted *through consensus* — every replica's
    /// [`Service::housekeep`] emits an [`abort_request`], the engine
    /// proposes it like any client request, and the decided abort releases
    /// the locks on all replicas identically. This closes the
    /// coordinator-crash lock leak without any replica acting unilaterally
    /// on local time.
    lease: Nanos,
    /// When each staged txid was first observed by housekeeping.
    /// Local-only: never enters the digest/snapshot (replicas stamp at
    /// their own housekeep ticks, so stamps differ across replicas).
    staged_at: BTreeMap<u64, Nanos>,
    /// Txids whose lease abort was already emitted (emit once; the
    /// decided abort is idempotent anyway). Local-only, like `staged_at`.
    abort_emitted: BTreeSet<u64>,
}

impl TxService {
    pub fn new(inner: Box<dyn Service>) -> TxService {
        TxService::with_lease(inner, 0)
    }

    /// A participant whose staged locks expire after `lease` ns
    /// (0 = never, the [`TxService::new`] behaviour).
    pub fn with_lease(inner: Box<dyn Service>, lease: Nanos) -> TxService {
        TxService {
            inner,
            locks: BTreeMap::new(),
            staged: BTreeMap::new(),
            tombstones: VecDeque::new(),
            tombstoned: BTreeSet::new(),
            lease,
            staged_at: BTreeMap::new(),
            abort_emitted: BTreeSet::new(),
        }
    }

    /// The wrapped application service.
    pub fn inner(&self) -> &dyn Service {
        self.inner.as_ref()
    }

    /// Number of currently locked keys.
    pub fn locked_keys(&self) -> usize {
        self.locks.len()
    }

    /// Number of prepared-but-undecided transactions.
    pub fn staged_txs(&self) -> usize {
        self.staged.len()
    }

    fn tombstone(&mut self, txid: u64) {
        if self.tombstoned.insert(txid) {
            self.tombstones.push_back(txid);
            if self.tombstones.len() > TOMBSTONE_CAP {
                if let Some(old) = self.tombstones.pop_front() {
                    self.tombstoned.remove(&old);
                }
            }
        }
    }

    fn unlock(&mut self, txid: u64) {
        self.locks.retain(|_, owner| *owner != txid);
    }

    fn locked(&self, req: &[u8]) -> bool {
        self.inner.keys(req).iter().any(|k| self.locks.contains_key(k))
    }

    fn prepare(&mut self, txid: u64, ops: Vec<Vec<u8>>) -> Vec<u8> {
        if self.tombstoned.contains(&txid) {
            return vec![TAG_CTL, TX_VOTE_ABORT];
        }
        if self.staged.contains_key(&txid) {
            // Duplicate prepare (e.g. re-decided after a view change).
            return vec![TAG_CTL, TX_VOTE_COMMIT];
        }
        let mut keys: BTreeSet<Vec<u8>> = BTreeSet::new();
        for op in &ops {
            for k in self.inner.keys(op) {
                keys.insert(k);
            }
        }
        let conflict = keys.iter().any(|k| self.locks.contains_key(k));
        let valid = !keys.is_empty() && ops.iter().all(|op| self.inner.validate(op));
        if conflict || !valid {
            self.tombstone(txid);
            return vec![TAG_CTL, TX_VOTE_ABORT];
        }
        for k in keys {
            self.locks.insert(k, txid);
        }
        self.staged.insert(txid, ops);
        vec![TAG_CTL, TX_VOTE_COMMIT]
    }

    fn commit(&mut self, txid: u64) -> Vec<u8> {
        let Some(ops) = self.staged.remove(&txid) else {
            return vec![TAG_CTL, TX_STALE];
        };
        self.unlock(txid);
        self.tombstone(txid);
        let results: Vec<Vec<u8>> = ops.iter().map(|op| self.inner.execute(op)).collect();
        committed_reply(&results)
    }

    fn abort(&mut self, txid: u64) -> Vec<u8> {
        self.staged.remove(&txid);
        self.unlock(txid);
        self.tombstone(txid);
        vec![TAG_CTL, TX_ABORTED]
    }

    fn meta_bytes(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        put_map(&mut w, &self.locks);
        w.u32(self.staged.len() as u32);
        for (txid, ops) in &self.staged {
            w.u64(*txid);
            put_list(&mut w, ops);
        }
        w.u32(self.tombstones.len() as u32);
        for t in &self.tombstones {
            w.u64(*t);
        }
        w.finish()
    }

    fn restore_meta(&mut self, meta: &[u8]) {
        let mut r = WireReader::new(meta);
        let Ok(locks) = get_map::<Vec<u8>, u64>(&mut r) else { return };
        let Ok(n_staged) = r.u32() else { return };
        let mut staged = BTreeMap::new();
        for _ in 0..n_staged {
            let Ok(txid) = r.u64() else { return };
            let Ok(ops) = get_list::<Vec<u8>>(&mut r) else { return };
            staged.insert(txid, ops);
        }
        let Ok(n_tomb) = r.u32() else { return };
        let mut tombstones = VecDeque::new();
        let mut tombstoned = BTreeSet::new();
        for _ in 0..n_tomb {
            let Ok(t) = r.u64() else { return };
            tombstoned.insert(t);
            tombstones.push_back(t);
        }
        self.locks = locks;
        self.staged = staged;
        self.tombstones = tombstones;
        self.tombstoned = tombstoned;
    }

    /// Split a [`TxService`] snapshot into `(participant meta bytes,
    /// inner application snapshot)`.
    pub fn split_snapshot(snap: &[u8]) -> Option<(Vec<u8>, Vec<u8>)> {
        let mut r = WireReader::new(snap);
        let meta = r.bytes().ok()?;
        let inner = r.bytes().ok()?;
        r.done().ok()?;
        Some((meta, inner))
    }

    /// The lock table recorded in a [`TxService`] snapshot.
    pub fn snapshot_locks(snap: &[u8]) -> Option<BTreeMap<Vec<u8>, u64>> {
        let (meta, _) = Self::split_snapshot(snap)?;
        let mut r = WireReader::new(&meta);
        get_map::<Vec<u8>, u64>(&mut r).ok()
    }

    /// The wrapped application's snapshot inside a [`TxService`] snapshot.
    pub fn inner_snapshot(snap: &[u8]) -> Option<Vec<u8>> {
        Self::split_snapshot(snap).map(|(_, inner)| inner)
    }
}

impl Checkpointable for TxService {
    fn digest(&self) -> Hash32 {
        let meta = self.meta_bytes();
        let inner = self.inner.digest();
        hash_parts(&[&meta[..], &inner.0[..]])
    }

    fn snapshot(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        w.bytes(&self.meta_bytes());
        w.bytes(&self.inner.snapshot());
        w.finish()
    }

    fn restore(&mut self, snap: &[u8]) {
        let Some((meta, inner)) = Self::split_snapshot(snap) else { return };
        self.restore_meta(&meta);
        self.inner.restore(&inner);
    }
}

impl Service for TxService {
    fn classify(&self, req: &[u8]) -> Operation {
        if req.first() == Some(&TAG_CTL) {
            Operation::ReadWrite
        } else {
            self.inner.classify(req)
        }
    }

    fn execute(&mut self, req: &[u8]) -> Vec<u8> {
        if let Some(ctl) = parse_ctl(req) {
            return match ctl {
                Ctl::Prepare { txid, ops } => self.prepare(txid, ops),
                Ctl::Commit { txid } => self.commit(txid),
                Ctl::Abort { txid } => self.abort(txid),
            };
        }
        if req.first() == Some(&TAG_CTL) {
            return vec![TAG_CTL, TX_STALE];
        }
        if self.locked(req) {
            return locked_reply();
        }
        self.inner.execute(req)
    }

    fn query(&self, req: &[u8]) -> Vec<u8> {
        if req.first() == Some(&TAG_CTL) {
            return vec![TAG_CTL, TX_STALE];
        }
        if self.locked(req) {
            return locked_reply();
        }
        self.inner.query(req)
    }

    fn keys(&self, req: &[u8]) -> Vec<Vec<u8>> {
        if req.first() == Some(&TAG_CTL) {
            Vec::new()
        } else {
            self.inner.keys(req)
        }
    }

    fn validate(&self, req: &[u8]) -> bool {
        if req.first() == Some(&TAG_CTL) {
            true
        } else {
            self.inner.validate(req)
        }
    }

    fn housekeep(&mut self, now: Nanos) -> Vec<Vec<u8>> {
        let mut out = self.inner.housekeep(now);
        if self.lease == 0 {
            return out;
        }
        // Stamps and emission flags are local-only bookkeeping: they never
        // enter the digest or snapshot, so housekeeping cannot diverge
        // replicated state. The only replicated effect is the emitted
        // abort request, which travels through consensus.
        self.staged_at.retain(|txid, _| self.staged.contains_key(txid));
        self.abort_emitted.retain(|txid| self.staged.contains_key(txid));
        let staged: Vec<u64> = self.staged.keys().copied().collect();
        for txid in staged {
            let at = *self.staged_at.entry(txid).or_insert(now);
            if now.saturating_sub(at) >= self.lease && self.abort_emitted.insert(txid) {
                out.push(abort_request(txid));
            }
        }
        out
    }

    fn sim_cost(&self, req: &[u8]) -> Nanos {
        match parse_ctl(req) {
            Some(Ctl::Prepare { ops, .. }) => {
                400 + ops.iter().map(|op| self.inner.sim_cost(op) / 2).sum::<Nanos>()
            }
            Some(Ctl::Commit { txid }) => {
                400 + self
                    .staged
                    .get(&txid)
                    .map_or(0, |ops| ops.iter().map(|op| self.inner.sim_cost(op)).sum())
            }
            Some(Ctl::Abort { .. }) => 400,
            None => self.inner.sim_cost(req),
        }
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

// ---------------------------------------------------------------------
// Client-side coordinator
// ---------------------------------------------------------------------

/// One sub-request the client must decide through a shard's consensus.
#[derive(Clone, Debug)]
pub struct SubReq {
    pub group: usize,
    pub payload: Vec<u8>,
}

/// What the client should do after feeding the coordinator a reply or a
/// timer tick.
#[derive(Debug)]
pub enum CoordEvent {
    None,
    /// Issue these sub-requests for `txid`.
    Issue { txid: u64, subs: Vec<SubReq> },
    /// The transaction finished; `resp` is the combined user-visible
    /// response (commit: [`TX_COMMITTED`] + per-group results in group
    /// order; abort: [`TX_ABORTED`]).
    Done { req: Vec<u8>, resp: Vec<u8>, sent_at: Nanos, committed: bool },
}

enum Phase {
    Preparing { votes: BTreeMap<usize, bool> },
    Deciding { commit: bool, acks: BTreeSet<usize>, results: BTreeMap<usize, Vec<u8>> },
}

struct Tx {
    req: Vec<u8>,
    sent_at: Nanos,
    groups: Vec<usize>,
    phase: Phase,
}

enum Next {
    None,
    Decide(bool),
    Finish,
}

/// Client-side two-phase-commit state machine. The [`crate::rpc::Client`]
/// drives it: `begin` on a new transaction, `on_reply` whenever a
/// sub-request completes, `expired` on retry ticks. The decision is a
/// one-way latch — an abort (vote or timeout) can never be overtaken by
/// a late commit vote, and participant tombstones void late prepares.
pub struct Coordinator {
    timeout: Nanos,
    txs: BTreeMap<u64, Tx>,
    /// Transactions that reached commit / abort, for stats.
    pub commits: u64,
    pub aborts: u64,
}

impl Coordinator {
    pub fn new(timeout: Nanos) -> Coordinator {
        Coordinator { timeout, txs: BTreeMap::new(), commits: 0, aborts: 0 }
    }

    pub fn set_timeout(&mut self, timeout: Nanos) {
        self.timeout = timeout;
    }

    /// In-flight (not yet decided-and-acked) transactions.
    pub fn active(&self) -> usize {
        self.txs.len()
    }

    /// Start a transaction: returns the prepare sub-requests, one per
    /// touched group. `ops_by_group` must be non-empty.
    pub fn begin(
        &mut self,
        txid: u64,
        req: Vec<u8>,
        ops_by_group: Vec<(usize, Vec<Vec<u8>>)>,
        now: Nanos,
    ) -> Vec<SubReq> {
        let groups: Vec<usize> = ops_by_group.iter().map(|(g, _)| *g).collect();
        let subs = ops_by_group
            .iter()
            .map(|(g, ops)| SubReq { group: *g, payload: prepare_request(txid, ops) })
            .collect();
        self.txs.insert(
            txid,
            Tx { req, sent_at: now, groups, phase: Phase::Preparing { votes: BTreeMap::new() } },
        );
        subs
    }

    /// Feed the completed reply of a sub-request for `txid` from `group`.
    pub fn on_reply(&mut self, txid: u64, group: usize, reply: &[u8]) -> CoordEvent {
        if reply.len() < 2 || reply[0] != TAG_CTL {
            return CoordEvent::None;
        }
        let kind = reply[1];
        let next = {
            let Some(tx) = self.txs.get_mut(&txid) else {
                return CoordEvent::None;
            };
            match &mut tx.phase {
                Phase::Preparing { votes } => match kind {
                    TX_VOTE_COMMIT => {
                        votes.insert(group, true);
                        if votes.len() == tx.groups.len() {
                            Next::Decide(true)
                        } else {
                            Next::None
                        }
                    }
                    TX_VOTE_ABORT => Next::Decide(false),
                    _ => Next::None,
                },
                Phase::Deciding { acks, results, .. } => match kind {
                    TX_COMMITTED | TX_ABORTED | TX_STALE => {
                        acks.insert(group);
                        if kind == TX_COMMITTED {
                            results.insert(group, reply.to_vec());
                        }
                        if acks.len() == tx.groups.len() {
                            Next::Finish
                        } else {
                            Next::None
                        }
                    }
                    // A late prepare vote after the decision: ignore.
                    _ => Next::None,
                },
            }
        };
        match next {
            Next::None => CoordEvent::None,
            Next::Decide(commit) => self.decide(txid, commit),
            Next::Finish => self.finish(txid),
        }
    }

    /// Abort every transaction whose prepare phase outlived the timeout;
    /// returns the decision sub-requests to issue. Called on retry ticks.
    pub fn expired(&mut self, now: Nanos) -> Vec<(u64, Vec<SubReq>)> {
        let mut stale: Vec<u64> = self
            .txs
            .iter()
            .filter(|(_, tx)| {
                matches!(tx.phase, Phase::Preparing { .. })
                    && now.saturating_sub(tx.sent_at) >= self.timeout
            })
            .map(|(txid, _)| *txid)
            .collect();
        stale.sort_unstable();
        stale
            .into_iter()
            .filter_map(|txid| match self.decide(txid, false) {
                CoordEvent::Issue { txid, subs } => Some((txid, subs)),
                _ => None,
            })
            .collect()
    }

    fn decide(&mut self, txid: u64, commit: bool) -> CoordEvent {
        let Some(tx) = self.txs.get_mut(&txid) else {
            return CoordEvent::None;
        };
        let subs = tx
            .groups
            .iter()
            .map(|&g| SubReq {
                group: g,
                payload: if commit { commit_request(txid) } else { abort_request(txid) },
            })
            .collect();
        tx.phase =
            Phase::Deciding { commit, acks: BTreeSet::new(), results: BTreeMap::new() };
        CoordEvent::Issue { txid, subs }
    }

    fn finish(&mut self, txid: u64) -> CoordEvent {
        let Some(tx) = self.txs.remove(&txid) else {
            return CoordEvent::None;
        };
        let Phase::Deciding { commit, results, .. } = tx.phase else {
            return CoordEvent::None;
        };
        let resp = if commit {
            let combined: Vec<Vec<u8>> = tx
                .groups
                .iter()
                .map(|g| results.get(g).cloned().unwrap_or_default())
                .collect();
            let mut w = WireWriter::new();
            w.u8(TAG_CTL);
            w.u8(TX_COMMITTED);
            put_list(&mut w, &combined);
            w.finish()
        } else {
            vec![TAG_CTL, TX_ABORTED]
        };
        if commit {
            self.commits += 1;
        } else {
            self.aborts += 1;
        }
        CoordEvent::Done { req: tx.req, resp, sent_at: tx.sent_at, committed: commit }
    }
}

// ---------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------

/// Steers client requests to their home shard. Each client owns one
/// router (a private [`Service`] instance is used purely for
/// [`Service::keys`] extraction — it never executes anything).
pub struct ShardRouter {
    service: Box<dyn Service>,
    partitioner: Arc<dyn Partitioner>,
    shards: usize,
}

impl ShardRouter {
    pub fn new(
        service: Box<dyn Service>,
        partitioner: Arc<dyn Partitioner>,
        shards: usize,
    ) -> ShardRouter {
        ShardRouter { service, partitioner, shards: shards.max(1) }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_of_key(&self, key: &[u8]) -> usize {
        self.partitioner.shard_of(key, self.shards).min(self.shards - 1)
    }

    /// Home group of a single-shard request. Requests without extractable
    /// keys go to group 0.
    pub fn home(&self, req: &[u8]) -> usize {
        match self.service.keys(req).first() {
            Some(k) => self.shard_of_key(k),
            None => 0,
        }
    }

    /// Group a transaction's ops by home shard (ascending shard order,
    /// preserving per-shard op order).
    pub fn op_groups(&self, ops: &[Vec<u8>]) -> Vec<(usize, Vec<Vec<u8>>)> {
        let mut by: BTreeMap<usize, Vec<Vec<u8>>> = BTreeMap::new();
        for op in ops {
            by.entry(self.home(op)).or_default().push(op.clone());
        }
        by.into_iter().collect()
    }
}

// ---------------------------------------------------------------------
// Hosting a replica at a shard-global actor id
// ---------------------------------------------------------------------

/// Environment adapter that lets an unmodified [`Replica`] built with a
/// *local* id `0..n` live at global actor id `base + local`. All node
/// ids crossing the boundary are translated: peer sends, SWMR register
/// owners (the simulator enforces write permission against global ids),
/// and `me()`. Ids `>= n` (clients) pass through untouched — client ids
/// start at `shards·n`, so the two ranges never collide. Memory-node
/// indices are a separate namespace shared by all shards; regions stay
/// disjoint because their owners are globalized.
struct ShardEnv<'a> {
    base: NodeId,
    n: usize,
    inner: &'a mut dyn Env,
}

impl ShardEnv<'_> {
    fn globalize(&self, id: NodeId) -> NodeId {
        if id < self.n {
            id + self.base
        } else {
            id
        }
    }
}

impl Env for ShardEnv<'_> {
    fn me(&self) -> NodeId {
        self.inner.me() - self.base
    }
    fn now(&self) -> Nanos {
        self.inner.now()
    }
    fn rng(&mut self) -> &mut Rng {
        self.inner.rng()
    }
    fn send(&mut self, dst: NodeId, bytes: Vec<u8>) {
        let dst = self.globalize(dst);
        self.inner.send(dst, bytes);
    }
    fn charge(&mut self, cat: Category, ns: Nanos) {
        self.inner.charge(cat, ns);
    }
    fn set_timer(&mut self, after: Nanos, token: u64) {
        self.inner.set_timer(after, token);
    }
    fn mem_write(&mut self, mem_node: usize, region: RegionId, bytes: Vec<u8>) -> Ticket {
        let region = RegionId { owner: self.globalize(region.owner), reg: region.reg };
        self.inner.mem_write(mem_node, region, bytes)
    }
    fn mem_read(&mut self, mem_node: usize, region: RegionId) -> Ticket {
        let region = RegionId { owner: self.globalize(region.owner), reg: region.reg };
        self.inner.mem_read(mem_node, region)
    }
    fn mark(&mut self, label: &'static str) {
        self.inner.mark(label);
    }
}

/// Actor wrapper hosting one shard-local [`Replica`] at a global actor
/// id. Incoming message sources from the replica's own group are
/// localized before delegation; everything else (client traffic, timer
/// tokens, memory completions) passes through unchanged.
pub struct ShardedReplica {
    base: NodeId,
    n: usize,
    inner: Replica,
}

impl ShardedReplica {
    pub fn new(base: NodeId, n: usize, inner: Replica) -> ShardedReplica {
        ShardedReplica { base, n, inner }
    }

    /// The wrapped consensus replica (for probes and state inspection).
    pub fn replica(&self) -> &Replica {
        &self.inner
    }

    /// First global actor id of this replica's group.
    pub fn base(&self) -> NodeId {
        self.base
    }
}

impl Actor for ShardedReplica {
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self) // deployment probes downcast to ShardedReplica
    }

    fn on_start(&mut self, env: &mut dyn Env) {
        let mut shard_env = ShardEnv { base: self.base, n: self.n, inner: env };
        self.inner.on_start(&mut shard_env);
    }

    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        let ev = match ev {
            Event::Recv { from, bytes } if from >= self.base && from < self.base + self.n => {
                Event::Recv { from: from - self.base, bytes }
            }
            other => other,
        };
        let mut shard_env = ShardEnv { base: self.base, n: self.n, inner: env };
        self.inner.on_event(&mut shard_env, ev);
    }
}

// ---------------------------------------------------------------------
// Spawner
// ---------------------------------------------------------------------

/// [`SystemSpawner`] for sharded deployments: `shards` independent uBFT
/// groups of `cfg.n` replicas each, every replica's application wrapped
/// in a [`TxService`] participant. Global actor ids are assigned
/// densely: group `s` occupies `s·n .. (s+1)·n`.
pub struct ShardSpawner {
    pub shards: usize,
}

impl SystemSpawner for ShardSpawner {
    fn spawn(&self, d: &Deployment, sink: &mut dyn ActorSink) -> Vec<NodeId> {
        let cfg: Config = d.config().clone();
        let mut ids = Vec::with_capacity(self.shards * cfg.n);
        for s in 0..self.shards {
            let base = s * cfg.n;
            for i in 0..cfg.n {
                let svc = Box::new(TxService::with_lease(d.make_service(), cfg.tx_lease_ns));
                // Persistence is keyed by the *global* actor id so every
                // replica of every group gets a distinct durable store.
                let replica =
                    Replica::with_persistence(i, cfg.clone(), svc, d.make_persistence(base + i));
                ids.push(sink.add_actor(Box::new(ShardedReplica::new(base, cfg.n, replica))));
            }
        }
        ids
    }

    fn quorum(&self, cfg: &Config) -> usize {
        cfg.quorum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::kv::{self, KvApp};

    fn txsvc() -> TxService {
        TxService::new(Box::new(KvApp::new()))
    }

    #[test]
    fn tx_request_round_trips() {
        let ops = vec![kv::set(b"a", b"1"), kv::set(b"b", b"2")];
        let req = tx_request(&ops);
        assert_eq!(parse_tx_request(&req), Some(ops));
        assert_eq!(parse_tx_request(&kv::set(b"a", b"1")), None);
        assert_eq!(parse_tx_request(&[TAG_TX]), None);
    }

    #[test]
    fn ctl_round_trips() {
        let ops = vec![kv::set(b"k", b"v")];
        assert_eq!(
            parse_ctl(&prepare_request(7, &ops)),
            Some(Ctl::Prepare { txid: 7, ops })
        );
        assert_eq!(parse_ctl(&commit_request(9)), Some(Ctl::Commit { txid: 9 }));
        assert_eq!(parse_ctl(&abort_request(3)), Some(Ctl::Abort { txid: 3 }));
        assert_eq!(parse_ctl(b"plain"), None);
    }

    #[test]
    fn hash_partitioner_is_stable_and_total() {
        let p = HashPartitioner;
        for shards in [1usize, 2, 3, 4, 7] {
            for i in 0..200u32 {
                let key = i.to_le_bytes();
                let s = p.shard_of(&key, shards);
                assert!(s < shards);
                assert_eq!(s, p.shard_of(&key, shards));
            }
        }
    }

    #[test]
    fn prepare_locks_and_commit_applies() {
        let mut svc = txsvc();
        let ops = vec![kv::set(b"acct", b"value-1")];
        let vote = svc.execute(&prepare_request(1, &ops));
        assert_eq!(vote, vec![TAG_CTL, TX_VOTE_COMMIT]);
        assert_eq!(svc.locked_keys(), 1);
        // A plain write against the locked key is rejected deterministically.
        assert!(is_locked(&svc.execute(&kv::set(b"acct", b"other"))));
        // ... and a read too.
        assert!(is_locked(&svc.query(&kv::get(b"acct"))));
        // An unrelated key is untouched.
        assert_eq!(svc.execute(&kv::set(b"free", b"x"))[0], kv::ST_OK);
        let reply = svc.execute(&commit_request(1));
        let results = parse_committed(&reply).expect("committed reply");
        assert_eq!(results.len(), 1);
        assert_eq!(svc.locked_keys(), 0);
        // The staged op actually executed.
        let got = svc.query(&kv::get(b"acct"));
        assert_eq!(got[0], kv::ST_OK);
        assert_eq!(&got[1..], b"value-1");
    }

    #[test]
    fn conflicting_prepare_votes_abort_and_tombstones() {
        let mut svc = txsvc();
        let ops = vec![kv::set(b"k", b"a")];
        assert_eq!(svc.execute(&prepare_request(1, &ops)), vec![TAG_CTL, TX_VOTE_COMMIT]);
        // A second transaction touching the same key conflicts.
        assert_eq!(svc.execute(&prepare_request(2, &ops)), vec![TAG_CTL, TX_VOTE_ABORT]);
        // The loser is tombstoned: a late duplicate prepare still aborts.
        assert_eq!(svc.execute(&prepare_request(2, &ops)), vec![TAG_CTL, TX_VOTE_ABORT]);
        // Aborting the winner releases the lock and voids later prepares.
        assert_eq!(svc.execute(&abort_request(1)), vec![TAG_CTL, TX_ABORTED]);
        assert_eq!(svc.locked_keys(), 0);
        assert_eq!(svc.execute(&prepare_request(1, &ops)), vec![TAG_CTL, TX_VOTE_ABORT]);
        // The key is free for plain ops again.
        assert_eq!(svc.execute(&kv::set(b"k", b"b"))[0], kv::ST_OK);
    }

    #[test]
    fn invalid_op_votes_abort_without_locking() {
        let mut svc = txsvc();
        // Overdraw: account does not exist, so a negative add must fail
        // validation at prepare time.
        let ops = vec![kv::add(b"acct", -5)];
        assert_eq!(svc.execute(&prepare_request(1, &ops)), vec![TAG_CTL, TX_VOTE_ABORT]);
        assert_eq!(svc.locked_keys(), 0);
        assert_eq!(svc.staged_txs(), 0);
    }

    #[test]
    fn lease_expiry_emits_one_consensus_abort() {
        let mut svc = TxService::with_lease(Box::new(KvApp::new()), 1_000);
        let ops = vec![kv::set(b"k", b"v")];
        svc.execute(&prepare_request(1, &ops));
        assert_eq!(svc.locked_keys(), 1);
        // First sighting stamps the txid; no abort before the lease runs out.
        assert!(svc.housekeep(100).is_empty());
        assert!(svc.housekeep(600).is_empty());
        // Lease expired: exactly one abort_request, never re-emitted.
        assert_eq!(svc.housekeep(1_100), vec![abort_request(1)]);
        assert!(svc.housekeep(2_000).is_empty());
        // Housekeeping never touches replicated state.
        let d0 = svc.digest();
        svc.housekeep(3_000);
        assert_eq!(svc.digest(), d0);
        // The decided abort (via consensus) releases the locks for good:
        // the tombstone voids any late prepare.
        assert_eq!(svc.execute(&abort_request(1)), vec![TAG_CTL, TX_ABORTED]);
        assert_eq!(svc.locked_keys(), 0);
        assert!(svc.housekeep(4_000).is_empty());
        assert_eq!(svc.execute(&prepare_request(1, &ops)), vec![TAG_CTL, TX_VOTE_ABORT]);
    }

    #[test]
    fn decided_tx_never_lease_aborts() {
        let mut svc = TxService::with_lease(Box::new(KvApp::new()), 1_000);
        let ops = vec![kv::set(b"k", b"v")];
        svc.execute(&prepare_request(1, &ops));
        svc.housekeep(0);
        svc.execute(&commit_request(1));
        assert!(svc.housekeep(5_000).is_empty());
        // new() keeps the lease off entirely.
        let mut off = txsvc();
        off.execute(&prepare_request(2, &ops));
        assert!(off.housekeep(u64::MAX / 2).is_empty());
        assert_eq!(off.locked_keys(), 1);
    }

    #[test]
    fn commit_of_unknown_tx_is_stale() {
        let mut svc = txsvc();
        assert_eq!(svc.execute(&commit_request(42)), vec![TAG_CTL, TX_STALE]);
    }

    #[test]
    fn snapshot_restores_mid_transaction_state() {
        let mut svc = txsvc();
        svc.execute(&kv::set(b"base", b"v"));
        let ops = vec![kv::set(b"locked", b"staged")];
        svc.execute(&prepare_request(5, &ops));
        let snap = svc.snapshot();
        let digest = svc.digest();
        assert_eq!(TxService::snapshot_locks(&snap).expect("locks").len(), 1);

        let mut fresh = txsvc();
        fresh.restore(&snap);
        assert_eq!(fresh.digest(), digest);
        assert_eq!(fresh.locked_keys(), 1);
        assert!(is_locked(&fresh.execute(&kv::set(b"locked", b"x"))));
        // The restored replica can still decide the staged transaction.
        let results = parse_committed(&fresh.execute(&commit_request(5))).expect("commit");
        assert_eq!(results.len(), 1);
        let got = fresh.query(&kv::get(b"locked"));
        assert_eq!(&got[1..], b"staged");
    }

    #[test]
    fn coordinator_commits_when_all_vote_commit() {
        let mut c = Coordinator::new(1_000_000);
        let subs = c.begin(
            1,
            b"user-req".to_vec(),
            vec![(0, vec![b"op0".to_vec()]), (2, vec![b"op2".to_vec()])],
            100,
        );
        assert_eq!(subs.len(), 2);
        assert!(matches!(c.on_reply(1, 0, &[TAG_CTL, TX_VOTE_COMMIT]), CoordEvent::None));
        let CoordEvent::Issue { txid, subs } = c.on_reply(1, 2, &[TAG_CTL, TX_VOTE_COMMIT])
        else {
            panic!("expected decision")
        };
        assert_eq!(txid, 1);
        assert_eq!(subs.len(), 2);
        assert!(subs.iter().all(|s| parse_ctl(&s.payload) == Some(Ctl::Commit { txid: 1 })));
        assert!(matches!(c.on_reply(1, 0, &committed_reply(&[b"r0".to_vec()])), CoordEvent::None));
        let CoordEvent::Done { resp, committed, .. } =
            c.on_reply(1, 2, &committed_reply(&[b"r2".to_vec()]))
        else {
            panic!("expected done")
        };
        assert!(committed);
        let per_group = parse_committed(&resp).expect("combined");
        assert_eq!(per_group.len(), 2);
        assert_eq!(c.commits, 1);
        assert_eq!(c.active(), 0);
    }

    #[test]
    fn coordinator_aborts_on_any_abort_vote() {
        let mut c = Coordinator::new(1_000_000);
        c.begin(7, vec![], vec![(0, vec![b"a".to_vec()]), (1, vec![b"b".to_vec()])], 0);
        let CoordEvent::Issue { subs, .. } = c.on_reply(7, 1, &[TAG_CTL, TX_VOTE_ABORT])
        else {
            panic!("expected abort decision")
        };
        assert!(subs.iter().all(|s| parse_ctl(&s.payload) == Some(Ctl::Abort { txid: 7 })));
        // A late commit vote from the other group cannot flip the latch.
        assert!(matches!(c.on_reply(7, 0, &[TAG_CTL, TX_VOTE_COMMIT]), CoordEvent::None));
        assert!(matches!(c.on_reply(7, 0, &[TAG_CTL, TX_ABORTED]), CoordEvent::None));
        let CoordEvent::Done { committed, resp, .. } = c.on_reply(7, 1, &[TAG_CTL, TX_ABORTED])
        else {
            panic!("expected done")
        };
        assert!(!committed);
        assert_eq!(resp, vec![TAG_CTL, TX_ABORTED]);
        assert_eq!(c.aborts, 1);
    }

    #[test]
    fn coordinator_times_out_stuck_prepares() {
        let mut c = Coordinator::new(1_000);
        c.begin(3, vec![], vec![(0, vec![b"a".to_vec()])], 0);
        assert!(c.expired(500).is_empty());
        let expired = c.expired(1_000);
        assert_eq!(expired.len(), 1);
        let (txid, subs) = &expired[0];
        assert_eq!(*txid, 3);
        assert!(parse_ctl(&subs[0].payload) == Some(Ctl::Abort { txid: 3 }));
        // Already deciding: a second tick does not re-abort.
        assert!(c.expired(2_000).is_empty());
        let CoordEvent::Done { committed, .. } = c.on_reply(3, 0, &[TAG_CTL, TX_ABORTED])
        else {
            panic!("expected done")
        };
        assert!(!committed);
    }

    #[test]
    fn router_groups_ops_by_home_shard() {
        let part = Arc::new(|key: &[u8], shards: usize| key[0] as usize % shards);
        let router = ShardRouter::new(Box::new(KvApp::new()), part, 4);
        assert_eq!(router.home(&kv::set(&[0, 1], b"x")), 0);
        assert_eq!(router.home(&kv::set(&[5, 1], b"x")), 1);
        let groups = router.op_groups(&[
            kv::set(&[1], b"a"),
            kv::set(&[2], b"b"),
            kv::set(&[5], b"c"),
        ]);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0, 1);
        assert_eq!(groups[0].1.len(), 2);
        assert_eq!(groups[1].0, 2);
    }
}
