//! `ubft` — CLI launcher for the uBFT reproduction.
//!
//! Evaluation commands regenerate the paper's figures/tables on the
//! deterministic discrete-event simulator (see README.md); `serve`
//! runs a real-thread deployment (see also `examples/`).

use ubft::cli::Args;
use ubft::harness;

const HELP: &str = "\
ubft — microsecond-scale BFT SMR (paper reproduction)

USAGE: ubft <command> [--samples N] [--seed S] [--config FILE]

EVALUATION (discrete-event simulator, paper §7):
  fig7        E2E latency of Flip/Memcached/Redis/Liquibook
  fig8        median E2E latency vs request size, all systems
  fig9        latency decomposition (RPC/CTB/SMR × P2P/Crypto/SWMR/Other)
  fig10       non-equivocation mechanisms vs message size
  fig11       tail latency vs CTBcast tail t
  table2      replica + disaggregated memory usage
  throughput  §9 throughput: batch size × pipeline depth, plus the KV
              speculation on/off sweep (emits BENCH_throughput.json)
  scaling     throughput vs concurrent clients + KV read-mix sweep
              (consensus vs linearizable vs direct read lane) + shard
              sweep (settlement workload across consensus groups;
              emits BENCH_scaling.json)
              [--reads PCT]  run only the read-mix smoke at PCT% reads
              [--shards N [--cross PCT]]  run only the shard smoke:
              1 group vs N groups at PCT% cross-shard txs (default 10)
              [--restart]  run only the durability smoke: sim-disk WAL
              replicas under rolling crash-restarts, zero write loss
  all         everything above

REAL MODE:
  serve       run a real-thread 3-replica KV deployment and a workload
              [--requests N]

MODEL CHECKING (see README.md \"Model checking\"):
  check       systematic schedule exploration over the deterministic sim
              [--scenario NAME]   target scenario (default base; --list)
              [--driver D]        dfs | dpor | random (default dfs)
              [--budget N]        total scheduler decisions (default 20000)
              [--depth N]         DFS/DPOR branching depth (default 40)
              [--seed S]          random-walk base seed
              [--mutation M]      re-install a known-fixed bug (--list)
              [--trace-out FILE]  write the shrunk counterexample trace
              [--replay FILE]     re-execute a recorded trace bit-for-bit
              [--list]            list scenarios and mutations
              exit code: 0 clean, 1 violation found/reproduced, 2 usage

MISC:
  lint        run the repo's static-analysis pass (alias for
              cargo run -p ubft-lint; see rust/tools/lint/README.md)
  calibration print the DES latency model constants
  help        this text

Set UBFT_SAMPLES to override per-point sample counts.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    let samples = args.get_usize("samples", 10_000).unwrap_or(10_000);
    if let Some(s) = args.get("samples") {
        std::env::set_var("UBFT_SAMPLES", s);
    }
    match args.command.as_str() {
        "fig7" => harness::fig7::main_run(samples),
        "fig8" => harness::fig8::main_run(samples),
        "fig9" => harness::fig9::main_run(samples),
        "fig10" => harness::fig10::main_run(samples),
        "fig11" => harness::fig11::main_run(samples),
        "table2" => harness::table2::main_run(samples),
        "throughput" => harness::throughput::main_run(samples),
        "scaling" if args.has_flag("restart") => harness::scaling::restart_smoke(samples),
        "scaling" => match (args.get_u64("reads", u64::MAX), args.get_u64("shards", u64::MAX)) {
            (Ok(u64::MAX), Ok(u64::MAX)) => harness::scaling::main_run(samples),
            (Ok(pct), Ok(u64::MAX)) if pct <= 100 => {
                harness::scaling::read_smoke(pct as u32, samples)
            }
            (Ok(u64::MAX), Ok(shards)) if (1..=16).contains(&shards) => {
                match args.get_u64("cross", 10) {
                    Ok(cross) if cross <= 100 => {
                        harness::scaling::shard_smoke(shards as usize, cross as u32, samples)
                    }
                    Ok(cross) => {
                        eprintln!("error: --cross {cross} outside 0..=100");
                        std::process::exit(2);
                    }
                    Err(e) => {
                        eprintln!("error: {e}");
                        std::process::exit(2);
                    }
                }
            }
            (Ok(pct), Ok(u64::MAX)) => {
                eprintln!("error: --reads {pct} outside 0..=100");
                std::process::exit(2);
            }
            (Ok(_), Ok(shards)) => {
                eprintln!("error: --shards {shards} outside 1..=16 (or combined with --reads)");
                std::process::exit(2);
            }
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        },
        "all" => {
            harness::fig7::main_run(samples);
            harness::fig8::main_run(samples);
            harness::fig9::main_run(samples);
            harness::fig10::main_run(samples);
            harness::fig11::main_run(samples);
            harness::table2::main_run(samples);
            harness::throughput::main_run(samples);
            harness::scaling::main_run(samples);
        }
        "serve" => serve(&args),
        "check" => std::process::exit(ubft::mc::cli_check(&args)),
        "lint" => std::process::exit(ubft_lint::cli_main(&[])),
        "calibration" => {
            let cfg = match args.get("config") {
                Some(path) => ubft::config::Config::load(path).expect("config"),
                None => ubft::config::Config::default(),
            };
            println!("{cfg:#?}");
        }
        _ => println!("{HELP}"),
    }
}

/// Real-thread deployment: 3 uBFT replicas + 1 client hammering a KV app.
fn serve(args: &Args) {
    use ubft::apps::kv::KvWorkload;
    use ubft::apps::KvApp;
    use ubft::config::{Config, SigBackend};
    use ubft::deploy::{Deployment, System};

    let requests = args.get_usize("requests", 2_000).unwrap_or(2_000);
    let mut cfg = Config::default();
    cfg.sig_backend = SigBackend::Ed25519; // real crypto in real mode
    let n = cfg.n;
    let mut cluster = Deployment::new(cfg)
        .system(System::UbftFast)
        .app(|| Box::new(KvApp::new()))
        .client(Box::new(KvWorkload::paper()))
        .requests(requests)
        .build_real()
        .expect("valid real-mode deployment");
    println!("real-mode deployment: {n} replicas + 1 client, {requests} requests…");
    let t0 = std::time::Instant::now();
    cluster.start();
    if !cluster.wait(std::time::Duration::from_secs(120)) {
        eprintln!("timed out");
    }
    let mut s = cluster.samples();
    cluster.stop();
    println!(
        "completed {} requests in {:.2}s — p50 {:.1} µs, p99 {:.1} µs, throughput {:.1} kops",
        s.len(),
        t0.elapsed().as_secs_f64(),
        s.median() as f64 / 1000.0,
        s.percentile(99.0) as f64 / 1000.0,
        s.len() as f64 / t0.elapsed().as_secs_f64() / 1000.0
    );
}
