//! MinBFT-style 2f+1 BFT SMR over a USIG trusted counter (Veronese et
//! al., the paper's main BFT comparison, §7.2/§7.4).
//!
//! Protocol (stable leader, the configuration the paper measures):
//! client → all replicas; the leader binds the request to its USIG
//! counter and multicasts PREPARE; followers verify both the client's
//! authenticator and the leader's UI inside the enclave, bind their own
//! UI and multicast COMMIT; a replica accepts once it holds f+1
//! commitments (the PREPARE counts as the leader's), executes, and
//! replies; the client waits for f+1 matching replies.
//!
//! Two configurations, as in the paper:
//! * **vanilla** — clients sign requests with public-key crypto and every
//!   replica verifies the signature;
//! * **HMAC** — clients also own an enclave, replacing public-key
//!   operations with USIG HMACs.
//!
//! Latency constants are calibrated to the paper's own measurements
//! (566 µs vanilla minimum E2E; enclave crossings 7–12.5 µs): MinBFT's
//! publicly available implementation is not µs-optimized, which the
//! paper addresses by swapping its TCP stack for VMA — the remaining
//! per-hop software overhead is [`HOP_OVERHEAD`].

use super::usig::{Usig, UI};
use crate::consensus::msgs::{direct_frame, parse_direct, DirectMsg, Request};
use crate::crypto::{hash, Hash32};
use crate::deploy::{ActorSink, Deployment, SystemSpawner};
use crate::env::{Actor, Env, Event};
use crate::metrics::Category;
use crate::smr::Service;
use crate::util::wire::{Wire, WireReader, WireWriter};
use crate::{NodeId, Nanos};
use std::collections::{BTreeSet, HashMap};

/// Per-message software overhead of the MinBFT codebase (calibrated so
/// the HMAC-only variant lands at the paper's Fig 8 values).
pub const HOP_OVERHEAD: Nanos = 78_000;
/// Vanilla client-side public-key signing cost (their crypto library;
/// calibrated so vanilla's minimum E2E ≈ the paper's 566 µs).
pub const VANILLA_CLIENT_SIGN: Nanos = 300_000;
/// Vanilla replica-side verification of a client signature.
pub const VANILLA_VERIFY: Nanos = 50_000;

const TAG_MB_PREPARE: u8 = 0x40;
const TAG_MB_COMMIT: u8 = 0x41;

fn put_ui(w: &mut WireWriter, ui: &UI) {
    w.u64(ui.signer as u64);
    w.u64(ui.counter);
    ui.mac.put(w);
}

fn get_ui(r: &mut WireReader) -> Option<UI> {
    Some(UI {
        signer: r.u64().ok()? as NodeId,
        counter: r.u64().ok()?,
        mac: Hash32::get(r).ok()?,
    })
}

/// [`SystemSpawner`] wiring for the two MinBFT configurations: `n`
/// replicas over a shared USIG secret; clients wait for f+1 replies.
pub struct Spawner {
    /// Vanilla (public-key clients) vs HMAC (enclave clients).
    pub vanilla: bool,
}

impl SystemSpawner for Spawner {
    fn spawn(&self, d: &Deployment, sink: &mut dyn ActorSink) -> Vec<NodeId> {
        let cfg = d.config();
        let secret = [0x5Au8; 32];
        for i in 0..cfg.n {
            sink.add_actor(Box::new(MinBftReplica::new(
                i,
                (0..cfg.n).collect(),
                cfg.f,
                self.vanilla,
                d.make_app(),
                secret,
            )));
        }
        (0..cfg.n).collect()
    }

    fn quorum(&self, cfg: &crate::config::Config) -> usize {
        cfg.quorum()
    }
}

struct SlotEntry {
    req: Request,
    client: NodeId,
    commitments: BTreeSet<NodeId>,
    executed: bool,
}

pub struct MinBftReplica {
    me: NodeId,
    replicas: Vec<NodeId>,
    f: usize,
    vanilla: bool,
    usig: Usig,
    app: Box<dyn Service>,
    next_seq: u64,
    slots: HashMap<u64, SlotEntry>,
    exec_next: u64,
}

impl MinBftReplica {
    pub fn new(
        me: NodeId,
        replicas: Vec<NodeId>,
        f: usize,
        vanilla: bool,
        app: Box<dyn Service>,
        secret: [u8; 32],
    ) -> MinBftReplica {
        MinBftReplica {
            me,
            replicas,
            f,
            vanilla,
            usig: Usig::new(me, secret),
            app,
            next_seq: 0,
            slots: HashMap::new(),
            exec_next: 0,
        }
    }

    fn is_leader(&self) -> bool {
        self.me == self.replicas[0]
    }

    fn charge_client_auth(&self, env: &mut dyn Env) {
        if self.vanilla {
            env.charge(Category::Crypto, VANILLA_VERIFY);
        } else {
            env.charge(Category::Crypto, Usig::CALL_NS);
        }
    }

    fn record_commitment(&mut self, env: &mut dyn Env, seq: u64, who: NodeId) {
        let Some(entry) = self.slots.get_mut(&seq) else { return };
        entry.commitments.insert(who);
        // Accept at f+1 distinct commitments; execute in sequence order.
        while let Some(e) = self.slots.get_mut(&self.exec_next) {
            if e.commitments.len() < self.f + 1 || e.executed {
                break;
            }
            e.executed = true;
            env.charge(Category::Other, self.app.sim_cost(&e.req.payload));
            let resp = self.app.execute(&e.req.payload);
            let frame = direct_frame(&DirectMsg::Response {
                rid: e.req.rid,
                slot: self.exec_next,
                payload: resp,
            });
            let client = e.client;
            env.send(client, frame);
            self.exec_next += 1;
        }
    }
}

impl Actor for MinBftReplica {
    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        let Event::Recv { from, bytes } = ev else { return };
        match bytes.first() {
            Some(&crate::tbcast::TAG_DIRECT) => {
                let Some(DirectMsg::Request(req)) = parse_direct(&bytes) else { return };
                env.charge(Category::Other, HOP_OVERHEAD);
                if !self.is_leader() {
                    return; // followers act on PREPARE (request is re-carried)
                }
                self.charge_client_auth(env);
                // Bind to the USIG counter and multicast PREPARE.
                env.charge(Category::Crypto, Usig::CALL_NS);
                let seq = self.next_seq;
                self.next_seq += 1;
                let body = req.encode();
                let ui = self.usig.create_ui(&body);
                let mut w = WireWriter::new();
                w.u8(TAG_MB_PREPARE);
                w.u64(seq);
                req.put(&mut w);
                put_ui(&mut w, &ui);
                let frame = w.finish();
                for &r in &self.replicas.clone() {
                    if r != self.me {
                        env.send(r, frame.clone());
                    }
                }
                self.slots.insert(
                    seq,
                    SlotEntry {
                        client: req.client as NodeId,
                        req,
                        commitments: [self.me].into(),
                        executed: false,
                    },
                );
            }
            Some(&TAG_MB_PREPARE) => {
                let mut r = WireReader::new(&bytes[1..]);
                let Ok(seq) = r.u64() else { return };
                let Ok(req) = Request::get(&mut r) else { return };
                let Some(ui) = get_ui(&mut r) else { return };
                env.charge(Category::Other, HOP_OVERHEAD);
                self.charge_client_auth(env);
                env.charge(Category::Crypto, Usig::CALL_NS); // verify leader UI
                if !self.usig.verify_ui(&ui, &req.encode()) {
                    return;
                }
                // Bind my own UI and multicast COMMIT.
                env.charge(Category::Crypto, Usig::CALL_NS);
                let digest = hash(&req.encode());
                let my_ui = self.usig.create_ui(&digest.0);
                let mut w = WireWriter::new();
                w.u8(TAG_MB_COMMIT);
                w.u64(seq);
                digest.put(&mut w);
                put_ui(&mut w, &my_ui);
                let frame = w.finish();
                for &rp in &self.replicas.clone() {
                    if rp != self.me {
                        env.send(rp, frame.clone());
                    }
                }
                self.slots.insert(
                    seq,
                    SlotEntry {
                        client: req.client as NodeId,
                        req,
                        commitments: [from, self.me].into(),
                        executed: false,
                    },
                );
                self.record_commitment(env, seq, self.me);
            }
            Some(&TAG_MB_COMMIT) => {
                let mut r = WireReader::new(&bytes[1..]);
                let Ok(seq) = r.u64() else { return };
                let Ok(_digest) = Hash32::get(&mut r) else { return };
                let Some(ui) = get_ui(&mut r) else { return };
                env.charge(Category::Other, HOP_OVERHEAD);
                env.charge(Category::Crypto, Usig::CALL_NS); // verify commit UI
                if !self.usig.check_mac(&ui, &_digest.0) {
                    return;
                }
                self.record_commitment(env, seq, from);
            }
            _ => {}
        }
    }
}

/// Client-side presend charge for the two configurations.
pub fn client_presend(vanilla: bool) -> Nanos {
    if vanilla {
        VANILLA_CLIENT_SIGN
    } else {
        Usig::CALL_NS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::{BytesWorkload, Client};
    use crate::sim::Sim;
    use crate::smr::NoopApp;

    fn run(vanilla: bool, reqs: usize) -> crate::metrics::Samples {
        let cfg = crate::config::Config::default();
        let mut sim = Sim::new(cfg.clone());
        let secret = [9u8; 32];
        for i in 0..3 {
            sim.add_actor(Box::new(MinBftReplica::new(
                i,
                vec![0, 1, 2],
                1,
                vanilla,
                Box::new(NoopApp::new()),
                secret,
            )));
        }
        let client = Client::new(Box::new(BytesWorkload { size: 32, label: "noop" }))
            .with_replicas(vec![0, 1, 2])
            .with_quorum(2)
            .with_max_requests(reqs)
            .with_presend_charge(client_presend(vanilla))
            .with_think(500 * crate::MICRO); // unloaded latency, as the paper measures
        let samples = client.samples_handle();
        sim.add_actor(Box::new(client));
        sim.run_until(10 * crate::SECOND);
        let s = samples.lock().unwrap().clone();
        s
    }

    #[test]
    fn vanilla_completes_at_papers_latency() {
        let mut s = run(true, 30);
        assert_eq!(s.len(), 30);
        let p50 = s.median() as f64 / 1000.0;
        // Paper: minimum end-to-end latency 566 µs (including the client's
        // public-key signature).
        assert!((450.0..700.0).contains(&p50), "vanilla MinBFT p50 = {p50} µs");
    }

    #[test]
    fn hmac_variant_is_faster() {
        let mut v = run(true, 20);
        let mut h = run(false, 20);
        assert_eq!(h.len(), 20);
        assert!(
            h.median() < v.median(),
            "HMAC variant ({}) must beat vanilla ({})",
            h.median(),
            v.median()
        );
        let p50 = h.median() as f64 / 1000.0;
        assert!((140.0..350.0).contains(&p50), "HMAC MinBFT p50 = {p50} µs");
    }
}
