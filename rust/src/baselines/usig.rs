//! USIG — Unique Sequential Identifier Generator, the trusted component
//! of MinBFT (Veronese et al.) and the SGX trusted counter of §7.4.
//!
//! Each process's enclave holds a monotonically increasing counter and a
//! shared secret. `create_ui(msg)` binds the message to the next counter
//! value with an HMAC: `HMAC(secret, msg ‖ counter ‖ process id)`; any
//! replica can verify via its own enclave. Because the counter never
//! repeats, a Byzantine process cannot assign the same identifier to two
//! different messages — non-equivocation from a trusted monotonic counter.
//!
//! The paper emulates SGX latency (no SGX on its RDMA testbed) with
//! measured enclave-crossing costs of 7–12.5 µs; [`Usig::CALL_NS`] mirrors
//! that and is charged by callers per enclave call.

use crate::crypto::{hmac, Hash32};
use crate::NodeId;

/// A unique identifier bound to a message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UI {
    pub signer: NodeId,
    pub counter: u64,
    pub mac: Hash32,
}

/// One process's view of the USIG service. All enclaves share `secret`
/// (provisioned at attestation time in real SGX deployments).
pub struct Usig {
    me: NodeId,
    secret: [u8; 32],
    counter: u64,
    /// Highest counter verified per remote signer (replay/sequence check).
    last_seen: std::collections::BTreeMap<NodeId, u64>,
}

impl Usig {
    /// Mean enclave-crossing latency (paper §7.4: 7–12.5 µs measured).
    pub const CALL_NS: crate::Nanos = 9_500;

    pub fn new(me: NodeId, secret: [u8; 32]) -> Usig {
        Usig { me, secret, counter: 0, last_seen: std::collections::BTreeMap::new() }
    }

    fn mac_for(&self, signer: NodeId, counter: u64, msg: &[u8]) -> Hash32 {
        let mut data = Vec::with_capacity(msg.len() + 16);
        data.extend_from_slice(msg);
        data.extend_from_slice(&counter.to_le_bytes());
        data.extend_from_slice(&(signer as u64).to_le_bytes());
        hmac(&self.secret, &data)
    }

    /// Enclave call: bind `msg` to the next counter value.
    pub fn create_ui(&mut self, msg: &[u8]) -> UI {
        self.counter += 1;
        UI { signer: self.me, counter: self.counter, mac: self.mac_for(self.me, self.counter, msg) }
    }

    /// Enclave call: verify a UI from another process. Enforces strictly
    /// increasing counters per signer (sequentiality).
    pub fn verify_ui(&mut self, ui: &UI, msg: &[u8]) -> bool {
        if self.mac_for(ui.signer, ui.counter, msg) != ui.mac {
            return false;
        }
        let last = self.last_seen.entry(ui.signer).or_insert(0);
        if ui.counter <= *last {
            return false; // replay or out-of-order
        }
        *last = ui.counter;
        true
    }

    /// Verification without sequence tracking (used when a message may be
    /// legitimately re-verified, e.g. on retransmission).
    pub fn check_mac(&self, ui: &UI, msg: &[u8]) -> bool {
        self.mac_for(ui.signer, ui.counter, msg) == ui.mac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Usig, Usig) {
        let secret = [7u8; 32];
        (Usig::new(0, secret), Usig::new(1, secret))
    }

    #[test]
    fn create_verify_roundtrip() {
        let (mut a, mut b) = pair();
        let ui = a.create_ui(b"m1");
        assert!(b.verify_ui(&ui, b"m1"));
    }

    #[test]
    fn counters_are_sequential() {
        let (mut a, _) = pair();
        assert_eq!(a.create_ui(b"x").counter, 1);
        assert_eq!(a.create_ui(b"y").counter, 2);
    }

    #[test]
    fn tampered_message_rejected() {
        let (mut a, mut b) = pair();
        let ui = a.create_ui(b"m1");
        assert!(!b.verify_ui(&ui, b"m2"));
    }

    #[test]
    fn replay_rejected() {
        let (mut a, mut b) = pair();
        let ui = a.create_ui(b"m");
        assert!(b.verify_ui(&ui, b"m"));
        assert!(!b.verify_ui(&ui, b"m"), "same counter must not verify twice");
    }

    #[test]
    fn equivocation_impossible_per_counter() {
        // A Byzantine process cannot produce two different messages bound
        // to the same counter without breaking the MAC.
        let (mut a, mut b) = pair();
        let ui1 = a.create_ui(b"v1");
        let mut forged = ui1.clone();
        // pretend v2 has the same counter
        assert!(!b.verify_ui(&forged, b"v2"));
        forged.mac = Hash32::ZERO;
        assert!(!b.verify_ui(&forged, b"v2"));
    }

    #[test]
    fn wrong_secret_rejected() {
        let mut a = Usig::new(0, [1u8; 32]);
        let mut b = Usig::new(1, [2u8; 32]);
        let ui = a.create_ui(b"m");
        assert!(!b.verify_ui(&ui, b"m"));
    }
}
