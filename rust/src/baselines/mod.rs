//! Baseline systems the paper compares against (§7.2, §7.4):
//!
//! * [`unreplicated::Server`] — a single unreplicated server (the "Unrepl."
//!   lines in Figs 7/8);
//! * [`mu::MuLeader`]/[`mu::MuFollower`] — a Mu-style crash-only SMR: the
//!   leader replicates requests by one-sided RDMA writes into follower
//!   logs and replies after a majority of write completions;
//! * [`usig::Usig`] — a MinBFT-style USIG (trusted monotonic counter +
//!   HMAC) with the enclave-crossing latency the paper measured for SGX;
//! * [`minbft::MinBftReplica`] — a MinBFT-style 2f+1 BFT SMR over USIG,
//!   in the paper's two configurations (vanilla: clients sign with
//!   public-key crypto; HMAC: clients use the enclave too).

pub mod minbft;
pub mod mu;
pub mod unreplicated;
pub mod usig;
