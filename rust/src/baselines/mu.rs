//! Mu-style crash-only SMR baseline (Aguilera et al., OSDI'20): the
//! fastest known SMR, tolerating only crash faults. In the absence of
//! failures the leader replicates a request by RDMA-writing it into its
//! followers' logs and replies to the client once a *majority* of writes
//! completed — followers' CPUs are not involved on the hot path.
//!
//! We model the one-sided log write as a message to the follower plus a
//! NIC-level completion that costs one wire RTT and zero follower CPU
//! (the follower actor acks with no processing charge, standing in for
//! the RDMA ACK). This lands Mu at the paper's measured overhead over
//! unreplicated execution (Fig 7/8) without modelling Mu's permission
//! management, which is off the common path.

use crate::consensus::msgs::{direct_frame, parse_direct, DirectMsg, Request};
use crate::deploy::{ActorSink, Deployment, SystemSpawner};
use crate::env::{Actor, Env, Event};
use crate::metrics::Category;
use crate::smr::Service;
use crate::util::wire::{Wire, WireReader, WireWriter};
use crate::NodeId;
use std::collections::HashMap;

/// Wire tag for Mu log writes/acks (distinct from TB/DIRECT frames).
const TAG_MU_LOG: u8 = 0x30;
const TAG_MU_ACK: u8 = 0x31;

pub struct MuLeader {
    followers: Vec<NodeId>,
    majority: usize, // follower acks needed (majority incl. self)
    app: Box<dyn Service>,
    next_seq: u64,
    pending: HashMap<u64, (NodeId, Request, usize)>,
    proc: crate::Nanos,
}

impl MuLeader {
    pub fn new(followers: Vec<NodeId>, app: Box<dyn Service>, cfg: &crate::config::Config) -> MuLeader {
        // n = followers + 1; majority of n includes the leader itself.
        let n = followers.len() + 1;
        let majority_total = n / 2 + 1;
        MuLeader {
            followers,
            majority: majority_total - 1,
            app,
            next_seq: 0,
            pending: HashMap::new(),
            proc: cfg.lat.proc_overhead,
        }
    }
}

/// [`SystemSpawner`] wiring for [`crate::deploy::System::Mu`]: one leader
/// (actor 0, the only node clients talk to) plus `n - 1` passive
/// followers whose logs the leader writes one-sidedly.
pub struct Spawner;

impl SystemSpawner for Spawner {
    fn spawn(&self, d: &Deployment, sink: &mut dyn ActorSink) -> Vec<NodeId> {
        let cfg = d.config();
        let leader = MuLeader::new((1..cfg.n).collect(), d.make_app(), cfg);
        sink.add_actor(Box::new(leader));
        for _ in 1..cfg.n {
            sink.add_actor(Box::new(MuFollower::new()));
        }
        vec![0]
    }

    fn quorum(&self, _cfg: &crate::config::Config) -> usize {
        1
    }
}

impl Actor for MuLeader {
    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        let Event::Recv { from, bytes } = ev else { return };
        match bytes.first() {
            Some(&crate::tbcast::TAG_DIRECT) => {
                let Some(DirectMsg::Request(req)) = parse_direct(&bytes) else { return };
                env.charge(Category::Other, self.proc);
                let seq = self.next_seq;
                self.next_seq += 1;
                // One-sided log write to every follower.
                let mut w = WireWriter::new();
                w.u8(TAG_MU_LOG);
                w.u64(seq);
                req.put(&mut w);
                let frame = w.finish();
                for &f in &self.followers {
                    env.send(f, frame.clone());
                }
                self.pending.insert(seq, (from, req, 0));
            }
            Some(&TAG_MU_ACK) => {
                let mut r = WireReader::new(&bytes[1..]);
                let Ok(seq) = r.u64() else { return };
                let Some(entry) = self.pending.get_mut(&seq) else { return };
                entry.2 += 1;
                if entry.2 == self.majority {
                    let (client, req, _) = self.pending.remove(&seq).unwrap();
                    env.charge(Category::Other, self.app.sim_cost(&req.payload));
                    let resp = self.app.execute(&req.payload);
                    env.send(
                        client,
                        direct_frame(&DirectMsg::Response {
                            rid: req.rid,
                            slot: seq,
                            payload: resp,
                        }),
                    );
                }
                let _ = from;
            }
            _ => {}
        }
    }
}

/// Passive follower: its log is written one-sidedly; the ACK models the
/// NIC-level RDMA write completion (zero CPU charge).
pub struct MuFollower {
    pub log: Vec<(u64, Request)>,
}

impl MuFollower {
    pub fn new() -> MuFollower {
        MuFollower { log: Vec::new() }
    }
}

impl Default for MuFollower {
    fn default() -> Self {
        Self::new()
    }
}

impl Actor for MuFollower {
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self) // tests downcast to inspect the replicated log
    }

    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        let Event::Recv { from, bytes } = ev else { return };
        if bytes.first() != Some(&TAG_MU_LOG) {
            return;
        }
        let mut r = WireReader::new(&bytes[1..]);
        let (Ok(seq), Ok(req)) = (r.u64(), Request::get(&mut r)) else { return };
        self.log.push((seq, req));
        // NIC-level completion: no processing charge.
        let mut w = WireWriter::new();
        w.u8(TAG_MU_ACK);
        w.u64(seq);
        env.send(from, w.finish());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::{BytesWorkload, Client};
    use crate::sim::Sim;
    use crate::smr::NoopApp;

    #[test]
    fn mu_replicates_and_stays_fast() {
        let cfg = crate::config::Config::default();
        let mut sim = Sim::new(cfg.clone());
        // ids 0..2: leader + 2 followers
        let leader =
            MuLeader::new(vec![1, 2], Box::new(NoopApp::new()), &cfg);
        sim.add_actor(Box::new(leader));
        sim.add_actor(Box::new(MuFollower::new()));
        sim.add_actor(Box::new(MuFollower::new()));
        let client = Client::new(Box::new(BytesWorkload { size: 32, label: "noop" }))
            .with_replicas(vec![0])
            .with_max_requests(200);
        let samples = client.samples_handle();
        sim.add_actor(Box::new(client));
        sim.run_until(crate::SECOND);
        let mut s = samples.lock().unwrap();
        assert_eq!(s.len(), 200);
        let p50 = s.median() as f64 / 1000.0;
        // Paper: Mu ≈ unreplicated + ~1.4 µs for small requests.
        assert!((2.5..7.0).contains(&p50), "Mu p50 = {p50} µs");
    }

    #[test]
    fn followers_hold_the_log() {
        let cfg = crate::config::Config::default();
        let mut sim = Sim::new(cfg.clone());
        sim.add_actor(Box::new(MuLeader::new(vec![1, 2], Box::new(NoopApp::new()), &cfg)));
        sim.add_actor(Box::new(MuFollower::new()));
        sim.add_actor(Box::new(MuFollower::new()));
        let client = Client::new(Box::new(BytesWorkload { size: 16, label: "noop" }))
            .with_replicas(vec![0])
            .with_max_requests(25);
        let samples = client.samples_handle();
        sim.add_actor(Box::new(client));
        sim.run_until(crate::SECOND);
        assert_eq!(samples.lock().unwrap().len(), 25);
        for f in 1..3 {
            let a = sim.actor_mut(f);
            let fo = a.as_any().unwrap().downcast_ref::<MuFollower>().unwrap();
            assert_eq!(fo.log.len(), 25);
        }
    }
}
