//! Unreplicated baseline: a single server executing requests directly —
//! the floor every replication protocol is measured against (Figs 7/8).

use crate::consensus::msgs::{direct_frame, parse_direct, DirectMsg};
use crate::deploy::{ActorSink, Deployment, SystemSpawner};
use crate::env::{Actor, Env, Event};
use crate::metrics::Category;
use crate::smr::Service;
use crate::NodeId;

pub struct Server {
    app: Box<dyn Service>,
    proc_overhead: crate::Nanos,
}

impl Server {
    pub fn new(app: Box<dyn Service>, cfg: &crate::config::Config) -> Server {
        Server { app, proc_overhead: cfg.lat.proc_overhead }
    }
}

/// [`SystemSpawner`] wiring for [`crate::deploy::System::Unreplicated`]:
/// a single server; clients accept its lone reply.
pub struct Spawner;

impl SystemSpawner for Spawner {
    fn spawn(&self, d: &Deployment, sink: &mut dyn ActorSink) -> Vec<NodeId> {
        let id = sink.add_actor(Box::new(Server::new(d.make_app(), d.config())));
        vec![id]
    }

    fn quorum(&self, _cfg: &crate::config::Config) -> usize {
        1
    }
}

impl Actor for Server {
    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        if let Event::Recv { from, bytes } = ev {
            if let Some(DirectMsg::Request(req)) = parse_direct(&bytes) {
                env.charge(Category::Other, self.proc_overhead);
                env.charge(Category::Other, self.app.sim_cost(&req.payload));
                let resp = self.app.execute(&req.payload);
                env.send(
                    from,
                    direct_frame(&DirectMsg::Response { rid: req.rid, slot: 0, payload: resp }),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::{BytesWorkload, Client};
    use crate::sim::Sim;
    use crate::smr::NoopApp;

    #[test]
    fn serves_requests_at_rpc_floor() {
        let cfg = crate::config::Config::default();
        let mut sim = Sim::new(cfg.clone());
        let server = Server::new(Box::new(NoopApp::new()), &cfg);
        let sid = sim.add_actor(Box::new(server));
        let client = Client::new(Box::new(BytesWorkload { size: 32, label: "noop" }))
            .with_replicas(vec![sid])
            .with_max_requests(100);
        let samples = client.samples_handle();
        sim.add_actor(Box::new(client));
        sim.run_until(crate::SECOND);
        let mut s = samples.lock().unwrap();
        assert_eq!(s.len(), 100);
        // One round trip + processing: ~2.2 µs for small requests (paper).
        let p50 = s.median() as f64 / 1000.0;
        assert!((1.5..4.0).contains(&p50), "unreplicated p50 = {p50} µs");
    }
}
