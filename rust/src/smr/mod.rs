//! State-machine-replication glue: the typed [`Service`] API every
//! replicated application implements, plus deterministic execution
//! bookkeeping.
//!
//! The consensus engine ([`crate::consensus::Replica`]) owns a `Box<dyn
//! Service>`, applies decided request *batches* in slot order through
//! [`Service::apply_batch`], serves [`Operation::ReadOnly`]-classified
//! requests from applied state through [`Service::query`] (the non-slot
//! read lane), and certifies/transfers the [`Checkpointable`] state in
//! checkpoints (§5.1). Applications live in [`crate::apps`].
//!
//! # Migrating from the seed's `App` trait
//!
//! The untyped `App` trait (one `execute(&mut self, &[u8]) -> Vec<u8>`
//! per request) was replaced by two traits:
//!
//! * [`Checkpointable`] — `digest` / `snapshot` / `restore`, now actually
//!   consumed by the protocol: checkpoints certify the snapshot digest
//!   and a lagging replica catches up by fetching the snapshot instead of
//!   replaying pre-checkpoint slots.
//! * [`Service`] — classification ([`Service::classify`]), per-request
//!   state transitions ([`Service::execute`]), the read lane
//!   ([`Service::query`]), and batch execution ([`Service::apply_batch`],
//!   the protocol-facing entry point; the default loops over `execute`).
//!
//! Mechanical changes for implementors:
//!
//! | seed (`App`)                  | now (`Service`)                               |
//! |-------------------------------|-----------------------------------------------|
//! | `impl App for X { execute, digest, snapshot, restore, sim_cost, name }` | `impl Checkpointable for X { digest, snapshot, restore }` + `impl Service for X { execute, sim_cost, name, … }` |
//! | `Box<dyn App>`                | `Box<dyn Service>`                            |
//! | `deploy::AppFactory`          | unchanged alias of `deploy::ServiceFactory`   |
//! | `Deployment::app(..)`         | unchanged (or the synonym `.service(..)`)     |
//! | every byte in a consensus slot| `classify` routes `ReadOnly` ops around consensus (`Deployment::reads(ReadMode::Direct)`) |
//!
//! Read-only requests **must not** mutate observable state: executing a
//! `ReadOnly`-classified request through `execute` (the consensus
//! fallback path) must leave [`Checkpointable::digest`] unchanged, and
//! `query` must answer it identically. This is what makes the read lane
//! safe to serve from any replica's applied state.
//!
//! # Speculation (the `SpeculativeService` capability)
//!
//! With [`crate::config::Config::speculation`] on (builder:
//! [`crate::deploy::Deployment::speculate`]), a replica executes a slot's
//! batch *when its PREPARE is delivered* — overlapping application
//! execution with the certification round trips — and `decide()` merely
//! *promotes* the speculation in constant time instead of running
//! [`Service::apply_batch`] on the client-visible critical path. The
//! capability is the undo-token triple on [`Service`]:
//!
//! * [`Service::apply_speculative`] — apply a batch and return a
//!   [`SpecToken`] that can undo it (plus the replies, which the replica
//!   pre-encodes but **withholds until decide**);
//! * [`Service::commit_speculation`] — the decided batch matched: fold
//!   the undo record (constant time for native implementations);
//! * [`Service::rollback_speculation`] — the speculation lost (view
//!   change re-proposed something else): restore the pre-speculation
//!   state exactly. Outstanding speculations are always unwound in LIFO
//!   order, and committed in FIFO order.
//!
//! The default adapter clones-and-restores through
//! [`Checkpointable::snapshot`] / [`Checkpointable::restore`], so every
//! existing `Service` speculates correctly out of the box; Kv, the
//! Redis-like store and the order book override the triple with native
//! per-operation undo logs. The contract that keeps speculation safe:
//! `apply_speculative` must produce byte-identical replies and digests
//! to `apply_batch` on the same state, and a rollback must restore a
//! byte-identical [`Checkpointable::snapshot`] (checkpoint certificates
//! hash that encoding across replicas). Safety is unaffected — only
//! *timing* moves: no speculative reply leaves the replica before the
//! slot decides, so a Byzantine leader cannot exfiltrate divergent
//! replies through speculation.
//!
//! # Sharded deployments
//!
//! [`crate::deploy::Deployment::shards`] partitions the keyspace across
//! N independent consensus groups (see [`crate::shard`]). Two optional
//! `Service` hooks drive it:
//!
//! * [`Service::keys`] — the keys a request touches. The client-side
//!   router sends each request (including direct/linearizable reads) to
//!   its first key's home group, and cross-shard transactions lock every
//!   returned key at prepare.
//! * [`Service::validate`] — a side-effect-free "would this execute
//!   successfully?" check, evaluated at prepare so a transaction only
//!   commits ops that cannot fail at commit time (the keys stay locked
//!   in between).
//!
//! Consistency under sharding: single-key operations remain linearizable
//! within their home shard exactly as in the single-group deployment
//! (each shard runs the full protocol, read lanes included, with
//! per-group session read bounds on the client). Multi-key operations
//! submitted as [`crate::shard::tx_request`] transactions are atomic and
//! serializable across shards via two-phase commit over strict two-phase
//! locking: plain operations conflicting with a held lock are rejected
//! with a deterministic `TX_LOCKED` reply rather than reordered.
//!
//! # Durability & recovery
//!
//! The [`persist`] submodule converts the failure model from crash-stop
//! to crash-recovery: behind the [`Persistence`] trait a replica keeps
//! an append-only WAL (certify endorsements, decided batches, view
//! changes) plus checkpointed snapshots, and on restart replays the WAL
//! onto its newest durable snapshot — f-independent recovery, no live
//! peer required. The default [`persist::InMemory`] backend keeps the
//! seed's memoryless behaviour (and the allocation-free hot path)
//! untouched; [`persist::SimDisk`] survives simulated crash-restart for
//! the model checker; [`persist::FileSystemLog`] writes real files with
//! async group-fsync. Reply-cache deltas deliberately ride the decided
//! batches rather than their own WAL records: recovery rebuilds the
//! at-most-once cache by re-executing the replayed batches, which keeps
//! the WAL smaller *and* cannot double-insert a reply. Time-driven
//! service housekeeping (the 2PC participant lease) hooks in through
//! [`Service::housekeep`], whose emitted operations are decided through
//! consensus like any other request — never applied locally out of
//! order.

use crate::consensus::msgs::Request;
use crate::crypto::Hash32;
use crate::Nanos;

pub mod persist;

pub use persist::{PersistMode, Persistence, Recovered};

/// How a request interacts with service state (the typed operation
/// classes of the `Service` API).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Operation {
    /// Observes state only. Eligible for the non-slot read lane: answered
    /// from applied state, never occupies a consensus slot.
    ReadOnly,
    /// May mutate state. Always ordered through Consistent Tail Broadcast.
    ReadWrite,
}

/// How clients route [`Operation::ReadOnly`] requests
/// ([`crate::deploy::Deployment::reads`]).
///
/// # Consistency model
///
/// | mode | guarantee | quorum rule | expected latency |
/// |---|---|---|---|
/// | [`ReadMode::Consensus`] | linearizable | request decided in a slot, f+1 matching responses | full consensus round |
/// | [`ReadMode::Linearizable`] | session-linearizable: read-your-writes always, cross-session freshness up to the f+1-vouched bound (f bound-deflating colluders can press that to the session floor — see the variant docs) | f+1 matching `ReadReply`s with `applied_upto ≥` the read index (the highest decided bound vouched by f+1 replicas, floored at the client's own completed writes) | ~1 RTT; one extra round when a replica must catch up |
/// | [`ReadMode::Direct`] | eventually consistent | f+1 matching `ReadReply`s, no freshness check | 1 RTT |
///
/// `Linearizable` and `Direct` never consume consensus slots; writes take
/// the identical Consistent-Tail-Broadcast path in all three modes.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ReadMode {
    /// Every request goes through a consensus slot (the seed's behaviour,
    /// and the default).
    Consensus,
    /// Read-only requests are sent on the direct read lane and complete on
    /// f+1 matching replies from applied state. Writes are unaffected, so
    /// agreement on state is untouched; a read may observe a replica a few
    /// slots behind the freshest commit — the documented
    /// eventually-consistent fast path.
    Direct,
    /// The read lane with the read-index freshness protocol: the
    /// `ReadRequest` fan-out doubles as an index fetch (every `ReadReply`
    /// vouches the replica's certified decided bound), the client computes
    /// the read index as the highest bound f+1 replicas vouch for (never
    /// below the slots of its own completed writes), and only completes on
    /// f+1 matching payloads served from state at least that fresh.
    /// Replicas park too-early reads until they apply up to the demanded
    /// index, so a briefly-lagging replica answers as soon as it catches
    /// up instead of forcing a client re-poll. Lagging-but-honest replicas
    /// can never serve a stale read in this mode, and a session always
    /// observes its own completed writes; cross-session freshness rests on
    /// the f+1-vouched bound, which f bound-deflating colluders can press
    /// down to the session floor (the f+1-quorum fast-read trade-off —
    /// see the [`crate::rpc`] module docs).
    Linearizable,
}

/// Undo token for one speculatively applied batch (the
/// `SpeculativeService` capability — see the [module docs](self)).
/// Returned by [`Service::apply_speculative`]; handed back to exactly one
/// of [`Service::commit_speculation`] (FIFO) or
/// [`Service::rollback_speculation`] (LIFO).
#[derive(Debug)]
pub enum SpecToken {
    /// Pre-speculation [`Checkpointable::snapshot`] held by the default
    /// clone-and-restore adapter.
    Snapshot(Vec<u8>),
    /// Identifier of a service-native undo record (the service keeps the
    /// undo log internally; cheap commit, surgical rollback).
    Native(u64),
}

/// One executed request's reply, produced by [`Service::apply_batch`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Reply {
    /// Client the originating request came from.
    pub client: u64,
    /// The request id the reply answers.
    pub rid: u64,
    /// Response payload sent back to the client.
    pub payload: Vec<u8>,
}

/// State that checkpoints certify and state transfer moves between
/// replicas. `digest` is the identity certified by f+1 checkpoint
/// signatures; `snapshot`/`restore` must round-trip digest-equal
/// (`restore(snapshot())` yields an identical digest on a fresh
/// instance) for snapshot-driven catch-up to converge.
pub trait Checkpointable {
    /// Digest of the current application state (certified by checkpoints).
    fn digest(&self) -> Hash32;

    /// Serialize the full state (fetched by lagging replicas instead of
    /// replaying pre-checkpoint slots).
    fn snapshot(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore from a snapshot produced by [`Checkpointable::snapshot`].
    fn restore(&mut self, _snap: &[u8]) {}
}

/// A deterministic replicated service (the typed successor of the seed's
/// `App` trait — see the [module docs](self) for the migration guide).
pub trait Service: Checkpointable + Send {
    /// Classify a request payload. `ReadOnly` requests are eligible for
    /// the read lane and **must not** mutate observable state when
    /// executed. Default: everything is a write.
    fn classify(&self, _req: &[u8]) -> Operation {
        Operation::ReadWrite
    }

    /// Apply one request, returning the response sent back to the client.
    /// Must be deterministic: all replicas execute the same sequence.
    fn execute(&mut self, req: &[u8]) -> Vec<u8>;

    /// Answer a [`Operation::ReadOnly`]-classified request from current
    /// state without mutating it (the read lane). Must agree with what
    /// [`Service::execute`] would answer for the same request against the
    /// same state. Only invoked for requests this service classified
    /// `ReadOnly`, so any service that overrides [`Service::classify`]
    /// must override `query` too — the default panics rather than let a
    /// forgotten override serve silently-empty replies to clients.
    fn query(&self, _req: &[u8]) -> Vec<u8> {
        panic!(
            "{}: classify() returned ReadOnly but query() is not implemented",
            self.name()
        )
    }

    /// Execute one decided slot's request batch, returning exactly one
    /// [`Reply`] per request, in batch order. This is the protocol-facing
    /// entry point; the default loops over [`Service::execute`]. Override
    /// to exploit intra-batch locality (shared index lookups, vectorized
    /// application) — replies must stay positionally aligned with `reqs`.
    fn apply_batch(&mut self, reqs: &[Request]) -> Vec<Reply> {
        reqs.iter()
            .map(|r| Reply {
                client: r.client,
                rid: r.rid,
                payload: self.execute(&r.payload),
            })
            .collect()
    }

    /// Speculatively execute one batch ahead of its decide, returning an
    /// undo token alongside the replies. Must be observably identical to
    /// [`Service::apply_batch`] (same replies, same digest); after a
    /// [`Service::rollback_speculation`] of the returned token the state
    /// must be byte-identical (per [`Checkpointable::snapshot`]) to the
    /// pre-call state. The default adapter clones-and-restores via
    /// snapshot, so every service with a faithful
    /// [`Checkpointable::snapshot`]/[`Checkpointable::restore`] pair
    /// supports speculation unmodified; override the triple with a
    /// native undo log to make it cheap.
    fn apply_speculative(&mut self, reqs: &[Request]) -> (SpecToken, Vec<Reply>) {
        let snap = self.snapshot();
        let replies = self.apply_batch(reqs);
        (SpecToken::Snapshot(snap), replies)
    }

    /// The speculated batch decided unchanged: discard its undo record.
    /// Tokens are committed oldest-first (FIFO). The default adapter has
    /// nothing to fold — dropping the snapshot commits it.
    fn commit_speculation(&mut self, _token: SpecToken) {}

    /// The speculated batch will not decide (view-change re-proposal,
    /// pruned slot): restore the pre-speculation state. Tokens are rolled
    /// back newest-first (LIFO), so a native undo log pops its tail.
    fn rollback_speculation(&mut self, token: SpecToken) {
        if let SpecToken::Snapshot(snap) = token {
            self.restore(&snap);
        }
    }

    /// The keys a request touches, for sharded deployments (see the
    /// [module docs](self)): the router steers a request to its first
    /// key's home shard, and the two-phase-commit participant locks
    /// every returned key at prepare. Services that never run sharded
    /// can keep the default (no keys → the request routes to shard 0
    /// and transactions over it vote abort).
    fn keys(&self, _req: &[u8]) -> Vec<Vec<u8>> {
        Vec::new()
    }

    /// Would this request execute successfully against current state?
    /// Used by the two-phase-commit participant at prepare time: a
    /// transaction stages only ops that validate, and the locks it
    /// holds until commit guarantee validation still holds when the
    /// staged ops finally execute. Must not mutate state. Default:
    /// everything validates.
    fn validate(&self, _req: &[u8]) -> bool {
        true
    }

    /// Time-driven housekeeping, called from the replica's periodic tick
    /// with the current (simulated or real) time. Returns request
    /// payloads the replica should *propose through consensus* on the
    /// service's behalf — e.g. the 2PC participant lease emitting an
    /// abort for a transaction whose coordinator went silent. Emitted
    /// operations are decided and applied in slot order on every
    /// replica; `housekeep` itself must not mutate digest-visible state
    /// (replicas tick at different times, so anything digest-visible
    /// here would diverge). Default: no housekeeping.
    fn housekeep(&mut self, _now: Nanos) -> Vec<Vec<u8>> {
        Vec::new()
    }

    /// Simulated execution cost charged by the DES per request (ns).
    /// Calibrated per application (Fig 7 workloads).
    fn sim_cost(&self, _req: &[u8]) -> Nanos {
        300
    }

    fn name(&self) -> &'static str;
}

/// Trivial no-op application (the paper's Fig 8/9 workload): echoes the
/// request payload back unchanged.
pub struct NoopApp {
    executed: u64,
}

impl NoopApp {
    pub fn new() -> NoopApp {
        NoopApp { executed: 0 }
    }
}

impl Default for NoopApp {
    fn default() -> Self {
        Self::new()
    }
}

impl Checkpointable for NoopApp {
    fn digest(&self) -> Hash32 {
        crate::crypto::hash(&self.executed.to_le_bytes())
    }
    fn snapshot(&self) -> Vec<u8> {
        self.executed.to_le_bytes().to_vec()
    }
    fn restore(&mut self, snap: &[u8]) {
        if snap.len() == 8 {
            self.executed = u64::from_le_bytes(snap.try_into().unwrap());
        }
    }
}

impl Service for NoopApp {
    fn execute(&mut self, req: &[u8]) -> Vec<u8> {
        self.executed += 1;
        req.to_vec()
    }
    fn sim_cost(&self, _req: &[u8]) -> Nanos {
        100
    }
    fn name(&self) -> &'static str {
        "noop"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_echoes_and_digest_tracks_count() {
        let mut a = NoopApp::new();
        let d0 = a.digest();
        assert_eq!(a.execute(b"xyz"), b"xyz");
        assert_ne!(a.digest(), d0);
    }

    #[test]
    fn noop_snapshot_restore() {
        let mut a = NoopApp::new();
        a.execute(b"1");
        a.execute(b"2");
        let snap = a.snapshot();
        let mut b = NoopApp::new();
        b.restore(&snap);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn default_apply_batch_aligns_replies_with_requests() {
        let mut a = NoopApp::new();
        let reqs: Vec<Request> = (0..3)
            .map(|i| Request { client: 10 + i, rid: 100 + i, payload: vec![i as u8; 4] })
            .collect();
        let replies = a.apply_batch(&reqs);
        assert_eq!(replies.len(), 3);
        for (req, reply) in reqs.iter().zip(&replies) {
            assert_eq!((reply.client, reply.rid), (req.client, req.rid));
            assert_eq!(reply.payload, req.payload);
        }
    }

    #[test]
    fn default_classification_is_readwrite() {
        let a = NoopApp::new();
        assert_eq!(a.classify(b"anything"), Operation::ReadWrite);
    }

    #[test]
    fn default_speculation_adapter_round_trips() {
        // Every Service speculates via the snapshot adapter: replies match
        // apply_batch, commit keeps the state, rollback restores it
        // byte-identically.
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request { client: i, rid: i, payload: vec![i as u8; 8] })
            .collect();
        let mut reference = NoopApp::new();
        let ref_replies = reference.apply_batch(&reqs);

        let mut spec = NoopApp::new();
        let snap0 = spec.snapshot();
        let (tok, replies) = spec.apply_speculative(&reqs);
        assert_eq!(replies, ref_replies);
        assert_eq!(spec.digest(), reference.digest());
        spec.rollback_speculation(tok);
        assert_eq!(spec.snapshot(), snap0, "rollback must restore bytes exactly");

        let (tok, _) = spec.apply_speculative(&reqs);
        spec.commit_speculation(tok);
        assert_eq!(spec.digest(), reference.digest());
    }
}
