//! State-machine-replication glue: the [`App`] trait every replicated
//! service implements, plus deterministic execution bookkeeping.
//!
//! The consensus engine ([`crate::consensus::Replica`]) owns a `Box<dyn
//! App>` and applies decided requests in slot order; checkpoints certify
//! the app digest (§5.1). Applications live in [`crate::apps`].

use crate::crypto::Hash32;
use crate::Nanos;

/// A deterministic replicated application.
pub trait App: Send {
    /// Apply one request, returning the response sent back to the client.
    /// Must be deterministic: all replicas execute the same sequence.
    fn execute(&mut self, req: &[u8]) -> Vec<u8>;

    /// Digest of the current application state (certified by checkpoints).
    fn digest(&self) -> Hash32;

    /// Serialize the full state (used by the state-transfer extension).
    fn snapshot(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore from a snapshot produced by [`App::snapshot`].
    fn restore(&mut self, _snap: &[u8]) {}

    /// Simulated execution cost charged by the DES per request (ns).
    /// Calibrated per application (Fig 7 workloads).
    fn sim_cost(&self, _req: &[u8]) -> Nanos {
        300
    }

    fn name(&self) -> &'static str;
}

/// Trivial no-op application (the paper's Fig 8/9 workload): echoes the
/// request payload back unchanged.
pub struct NoopApp {
    executed: u64,
}

impl NoopApp {
    pub fn new() -> NoopApp {
        NoopApp { executed: 0 }
    }
}

impl Default for NoopApp {
    fn default() -> Self {
        Self::new()
    }
}

impl App for NoopApp {
    fn execute(&mut self, req: &[u8]) -> Vec<u8> {
        self.executed += 1;
        req.to_vec()
    }
    fn digest(&self) -> Hash32 {
        crate::crypto::hash(&self.executed.to_le_bytes())
    }
    fn snapshot(&self) -> Vec<u8> {
        self.executed.to_le_bytes().to_vec()
    }
    fn restore(&mut self, snap: &[u8]) {
        if snap.len() == 8 {
            self.executed = u64::from_le_bytes(snap.try_into().unwrap());
        }
    }
    fn sim_cost(&self, _req: &[u8]) -> Nanos {
        100
    }
    fn name(&self) -> &'static str {
        "noop"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_echoes_and_digest_tracks_count() {
        let mut a = NoopApp::new();
        let d0 = a.digest();
        assert_eq!(a.execute(b"xyz"), b"xyz");
        assert_ne!(a.digest(), d0);
    }

    #[test]
    fn noop_snapshot_restore() {
        let mut a = NoopApp::new();
        a.execute(b"1");
        a.execute(b"2");
        let snap = a.snapshot();
        let mut b = NoopApp::new();
        b.restore(&snap);
        assert_eq!(a.digest(), b.digest());
    }
}
