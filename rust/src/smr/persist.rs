//! Durable replica state behind the [`Persistence`] trait: an
//! append-only write-ahead log plus checkpointed snapshots, so a
//! restarted replica recovers f-independently (from its *own* durable
//! state) instead of relying on live peers.
//!
//! Three backends:
//!
//! * [`InMemory`] — the default. `durable()` is `false` and every hook
//!   is a no-op the consensus engine gates on, so the 10µs hot path and
//!   same-seed byte-identical behaviour are untouched.
//! * [`SimDisk`] — a deterministic in-sim store ([`SimDiskStore`],
//!   shared behind `Arc<Mutex<..>>`) that survives actor crash-restart
//!   under the DES. This is what the model checker's restart injection
//!   and the `it_recovery` tests run on.
//! * [`FileSystemLog`] — real files with **async group-fsync**: the
//!   protocol thread only sends bytes down a channel; a background
//!   worker coalesces appends for one fsync interval and issues a
//!   single `write + fdatasync` per group, amortizing durability off
//!   the decide critical path (the rabia/febft batched-persistence
//!   idiom).
//!
//! # Record framing
//!
//! Every WAL record is framed as `[u32 len][u32 crc][u64 slot][bytes]`
//! (little-endian; `len` covers the slot stamp plus the payload, `crc`
//! is the first four bytes of the payload hash over the same region).
//! A torn or truncated final record — the expected artifact of losing
//! power mid-write — fails the length or CRC check and is dropped;
//! everything before it replays cleanly ([`parse_records`] reports the
//! torn tail so recovery can count it). The `slot` stamp is opaque to
//! the framing and lets backends prune records a checkpointed snapshot
//! already covers (records that must survive pruning — view changes —
//! are stamped [`RETAIN`]).

use crate::{NodeId, Nanos};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Slot stamp for records that must survive snapshot pruning (view
/// changes: the recovered view is derivable only from the WAL).
pub const RETAIN: u64 = u64::MAX;

/// Frame header bytes: `u32` length + `u32` CRC.
const FRAME_HEADER: usize = 8;

/// How a deployment persists replica state
/// ([`crate::deploy::Deployment::persistence`] /
/// [`crate::config::Config::persistence`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum PersistMode {
    /// No durability (the seed behaviour, and the default): a crashed
    /// replica is memoryless and can only rejoin via live snapshot
    /// transfer from peers.
    InMemory,
    /// Deterministic in-sim store surviving actor crash-restart
    /// (sim-only; required by restart fault injection).
    SimDisk,
    /// Real files under [`crate::config::Config::persist_dir`] with
    /// async group-fsync batching.
    FileSystem,
}

/// Everything a replica's durable state yields at boot.
#[derive(Debug, Default)]
pub struct Recovered {
    /// Newest durable checkpoint snapshot: `(upto, bytes)` as handed to
    /// [`Persistence::put_snapshot`].
    pub snapshot: Option<(u64, Vec<u8>)>,
    /// WAL records `(slot stamp, payload)` in append order, torn tail
    /// (if any) already dropped.
    pub wal: Vec<(u64, Vec<u8>)>,
    /// The final WAL record was torn/truncated and was discarded.
    pub torn_tail: bool,
}

/// Append-only WAL + checkpointed snapshots. One instance per replica;
/// the consensus engine appends at certify/decide/view-change time,
/// snapshots at checkpoint time, and calls [`Persistence::recover`]
/// once at construction.
///
/// Contract: `append` must be cheap enough for the decide path (the
/// durable backends defer the actual I/O), and `recover` must return
/// exactly what earlier `append`/`put_snapshot` calls made durable —
/// minus at most one torn final record.
pub trait Persistence: Send {
    /// Does this backend retain anything across a crash? The consensus
    /// engine skips all encoding work when this is `false`, keeping the
    /// default hot path allocation-free and byte-identical to the seed.
    fn durable(&self) -> bool;

    /// Append one framed record stamped with `slot` (or [`RETAIN`]).
    fn append(&mut self, slot: u64, rec: &[u8]);

    /// Durability barrier: block until every prior append is on stable
    /// storage. Tests and shutdown paths use it; the decide path never
    /// does.
    fn sync(&mut self);

    /// Store the checkpointed snapshot at `upto` and prune WAL records
    /// whose slot stamp it covers (`slot < upto`, [`RETAIN`] excepted).
    fn put_snapshot(&mut self, upto: u64, bytes: &[u8]);

    /// Read back the durable state (called once, at replica boot).
    fn recover(&mut self) -> Recovered;

    /// Bytes currently held by the WAL (for the Table-2 style memory
    /// accounting; 0 for [`InMemory`]).
    fn wal_bytes(&self) -> u64;
}

/// CRC over a framed record body: first four bytes of the payload hash.
fn crc_of(body: &[u8]) -> u32 {
    let h = crate::crypto::hash(body);
    u32::from_le_bytes([h.0[0], h.0[1], h.0[2], h.0[3]])
}

/// Frame one record onto `out`: `[u32 len][u32 crc][u64 slot][rec]`.
pub fn frame_record(out: &mut Vec<u8>, slot: u64, rec: &[u8]) {
    let len = (8 + rec.len()) as u32;
    out.extend_from_slice(&len.to_le_bytes());
    let mut body = Vec::with_capacity(8 + rec.len());
    body.extend_from_slice(&slot.to_le_bytes());
    body.extend_from_slice(rec);
    out.extend_from_slice(&crc_of(&body).to_le_bytes());
    out.extend_from_slice(&body);
}

/// Parse a framed WAL byte stream into `(slot, payload)` records,
/// dropping a torn/truncated/corrupt tail. Returns the records plus
/// whether a tail was dropped.
pub fn parse_records(bytes: &[u8]) -> (Vec<(u64, Vec<u8>)>, bool) {
    let mut out = Vec::new();
    let mut off = 0usize;
    while off < bytes.len() {
        if off + FRAME_HEADER > bytes.len() {
            return (out, true); // torn mid-header
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        let body_at = off + FRAME_HEADER;
        if len < 8 || body_at + len > bytes.len() {
            return (out, true); // torn mid-body (or nonsense length)
        }
        let body = &bytes[body_at..body_at + len];
        if crc_of(body) != crc {
            return (out, true); // corrupt bytes: treat as the torn tail
        }
        let slot = u64::from_le_bytes(body[..8].try_into().unwrap());
        out.push((slot, body[8..].to_vec()));
        off = body_at + len;
    }
    (out, false)
}

/// Re-frame a record list into one contiguous byte stream (snapshot
/// pruning rewrites the WAL through this).
fn frame_all(records: &[(u64, Vec<u8>)]) -> Vec<u8> {
    let mut out = Vec::new();
    for (slot, rec) in records {
        frame_record(&mut out, *slot, rec);
    }
    out
}

// ---------------------------------------------------------------------
// InMemory — the no-op default
// ---------------------------------------------------------------------

/// The default backend: nothing survives a crash, nothing is spent on
/// the hot path. `durable()` is `false`, so the consensus engine never
/// even encodes a WAL record.
#[derive(Default)]
pub struct InMemory;

impl Persistence for InMemory {
    fn durable(&self) -> bool {
        false
    }
    fn append(&mut self, _slot: u64, _rec: &[u8]) {}
    fn sync(&mut self) {}
    fn put_snapshot(&mut self, _upto: u64, _bytes: &[u8]) {}
    fn recover(&mut self) -> Recovered {
        Recovered::default()
    }
    fn wal_bytes(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------
// SimDisk — deterministic in-sim durability
// ---------------------------------------------------------------------

/// Per-node durable state inside a [`SimDiskStore`].
#[derive(Default)]
struct NodeStore {
    /// Framed WAL byte stream (exactly what a file would hold).
    wal: Vec<u8>,
    /// Newest checkpoint snapshot: `(upto, bytes)`.
    snapshot: Option<(u64, Vec<u8>)>,
}

/// The "disk" of a simulated deployment: one durable region per node,
/// living *outside* the actors so it survives crash-restart. The
/// deployment builder creates one shared store per cluster and hands
/// each replica a [`SimDisk`] handle onto it.
#[derive(Default)]
pub struct SimDiskStore {
    nodes: BTreeMap<NodeId, NodeStore>,
}

/// Shared handle to the cluster's [`SimDiskStore`].
pub type SharedSimDisk = Arc<Mutex<SimDiskStore>>;

impl SimDiskStore {
    pub fn new() -> SimDiskStore {
        SimDiskStore::default()
    }

    /// A fresh store behind the shared handle the builder distributes.
    pub fn shared() -> SharedSimDisk {
        Arc::new(Mutex::new(SimDiskStore::new()))
    }

    /// Fault injection: tear the final WAL record of `node` — chop the
    /// byte stream mid-record, exactly what losing power inside a write
    /// leaves behind. Returns `false` when the node has no record to
    /// tear. Used by the `wal-torn-tail` checker scenario.
    pub fn tear_tail(&mut self, node: NodeId) -> bool {
        let Some(ns) = self.nodes.get_mut(&node) else { return false };
        // Walk the frames to find where the last complete record starts.
        let mut off = 0usize;
        let mut last: Option<(usize, usize)> = None; // (start, body len)
        while off + FRAME_HEADER <= ns.wal.len() {
            let len = u32::from_le_bytes(ns.wal[off..off + 4].try_into().unwrap()) as usize;
            let end = off + FRAME_HEADER + len;
            if len < 8 || end > ns.wal.len() {
                break;
            }
            last = Some((off, len));
            off = end;
        }
        let Some((start, len)) = last else { return false };
        // Keep the header plus roughly half the body: a CRC-failing,
        // length-plausible torn tail.
        ns.wal.truncate(start + FRAME_HEADER + len / 2);
        true
    }

    /// Total durable bytes across all nodes (tests / accounting).
    pub fn total_bytes(&self) -> u64 {
        self.nodes
            .values()
            .map(|ns| {
                ns.wal.len() as u64
                    + ns.snapshot.as_ref().map_or(0, |(_, s)| s.len() as u64)
            })
            .sum()
    }
}

/// One replica's handle onto the shared [`SimDiskStore`].
pub struct SimDisk {
    node: NodeId,
    store: SharedSimDisk,
}

impl SimDisk {
    pub fn new(node: NodeId, store: SharedSimDisk) -> SimDisk {
        SimDisk { node, store }
    }
}

impl Persistence for SimDisk {
    fn durable(&self) -> bool {
        true
    }

    fn append(&mut self, slot: u64, rec: &[u8]) {
        let mut store = self.store.lock().unwrap();
        let ns = store.nodes.entry(self.node).or_default();
        frame_record(&mut ns.wal, slot, rec);
    }

    fn sync(&mut self) {}

    fn put_snapshot(&mut self, upto: u64, bytes: &[u8]) {
        let mut store = self.store.lock().unwrap();
        let ns = store.nodes.entry(self.node).or_default();
        // Prune covered records; RETAIN-stamped ones always survive. A
        // torn tail (only possible after injected tearing) is dropped
        // here exactly as recovery would drop it.
        let (records, _) = parse_records(&ns.wal);
        let kept: Vec<(u64, Vec<u8>)> =
            records.into_iter().filter(|(s, _)| *s == RETAIN || *s >= upto).collect();
        ns.wal = frame_all(&kept);
        ns.snapshot = Some((upto, bytes.to_vec()));
    }

    fn recover(&mut self) -> Recovered {
        let store = self.store.lock().unwrap();
        let Some(ns) = store.nodes.get(&self.node) else {
            return Recovered::default();
        };
        let (wal, torn_tail) = parse_records(&ns.wal);
        Recovered { snapshot: ns.snapshot.clone(), wal, torn_tail }
    }

    fn wal_bytes(&self) -> u64 {
        let store = self.store.lock().unwrap();
        store.nodes.get(&self.node).map_or(0, |ns| ns.wal.len() as u64)
    }
}

// ---------------------------------------------------------------------
// FileSystemLog — real files, async group-fsync
// ---------------------------------------------------------------------

/// Commands the protocol thread sends the fsync worker.
enum FsCmd {
    /// Framed bytes to append to the WAL.
    Append(Vec<u8>),
    /// Durability barrier: flush + fsync, then ack.
    Sync(std::sync::mpsc::SyncSender<()>),
    /// Install a checkpoint snapshot and prune the WAL, then ack.
    Snapshot { upto: u64, bytes: Vec<u8>, ack: std::sync::mpsc::SyncSender<()> },
    Shutdown,
}

/// Real-file backend: `wal-<node>.log` + `snap-<node>.bin` under a
/// directory, written by a background worker that groups appends into
/// one `write + fdatasync` per fsync interval — durability cost is
/// amortized off the decide critical path (the protocol thread only
/// performs a channel send).
///
/// Real mode only: the background thread and its wall-clock interval
/// are exactly what the deterministic simulator must not contain, so
/// `deploy::validate` rejects this backend under the DES.
pub struct FileSystemLog {
    tx: std::sync::mpsc::Sender<FsCmd>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// What `recover` will report (read at open, before the worker owns
    /// the files).
    recovered: Option<Recovered>,
    /// WAL bytes appended since the last snapshot (approximate — the
    /// pruned tail retained across a snapshot is not re-counted).
    appended: u64,
}

impl FileSystemLog {
    /// WAL file path for `node` under `dir`.
    pub fn wal_path(dir: &std::path::Path, node: NodeId) -> std::path::PathBuf {
        dir.join(format!("wal-{node}.log"))
    }

    /// Snapshot file path for `node` under `dir`.
    pub fn snap_path(dir: &std::path::Path, node: NodeId) -> std::path::PathBuf {
        dir.join(format!("snap-{node}.bin"))
    }

    /// Open (creating `dir` if needed), recover existing durable state,
    /// and start the fsync worker with the given group interval.
    pub fn open(
        dir: &std::path::Path,
        node: NodeId,
        fsync_interval: Nanos,
    ) -> std::io::Result<FileSystemLog> {
        std::fs::create_dir_all(dir)?;
        let wal_path = Self::wal_path(dir, node);
        let snap_path = Self::snap_path(dir, node);

        // Recover before the worker takes over the files.
        let wal_bytes = std::fs::read(&wal_path).unwrap_or_default();
        let (wal, torn_tail) = parse_records(&wal_bytes);
        let snapshot = std::fs::read(&snap_path).ok().and_then(|b| {
            if b.len() < 8 {
                return None;
            }
            let upto = u64::from_le_bytes(b[..8].try_into().unwrap());
            Some((upto, b[8..].to_vec()))
        });
        // A recovered torn tail is dropped on disk too, so a second
        // crash-before-append cannot resurrect it.
        if torn_tail {
            let clean = frame_all(&wal);
            std::fs::write(&wal_path, &clean)?;
        }
        let recovered = Recovered { snapshot, wal, torn_tail };

        let (tx, rx) = std::sync::mpsc::channel::<FsCmd>();
        let interval = std::time::Duration::from_nanos(fsync_interval.max(1));
        let worker = std::thread::Builder::new()
            .name(format!("ubft-fsync-{node}"))
            .spawn(move || fsync_worker(rx, wal_path, snap_path, interval))
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))?;
        Ok(FileSystemLog { tx, worker: Some(worker), recovered: Some(recovered), appended: 0 })
    }
}

/// The group-fsync worker: blocks for the first dirty append, coalesces
/// everything that arrives within one fsync interval, then issues a
/// single `write + fdatasync` for the whole group.
fn fsync_worker(
    rx: std::sync::mpsc::Receiver<FsCmd>,
    wal_path: std::path::PathBuf,
    snap_path: std::path::PathBuf,
    interval: std::time::Duration,
) {
    use std::io::Write;
    let mut wal = match std::fs::OpenOptions::new().create(true).append(true).open(&wal_path) {
        Ok(f) => f,
        Err(_) => return, // unusable directory: appends are dropped
    };
    let mut pending: Vec<u8> = Vec::new();
    let mut acks: Vec<std::sync::mpsc::SyncSender<()>> = Vec::new();
    'outer: loop {
        // Block for the first command of the next group.
        let first = match rx.recv() {
            Ok(cmd) => cmd,
            Err(_) => break,
        };
        let mut shutdown = false;
        let mut snapshot: Option<(u64, Vec<u8>, std::sync::mpsc::SyncSender<()>)> = None;
        fn fold(
            cmd: FsCmd,
            pending: &mut Vec<u8>,
            acks: &mut Vec<std::sync::mpsc::SyncSender<()>>,
            snapshot: &mut Option<(u64, Vec<u8>, std::sync::mpsc::SyncSender<()>)>,
            shutdown: &mut bool,
        ) {
            match cmd {
                FsCmd::Append(bytes) => pending.extend_from_slice(&bytes),
                FsCmd::Sync(ack) => acks.push(ack),
                FsCmd::Snapshot { upto, bytes, ack } => *snapshot = Some((upto, bytes, ack)),
                FsCmd::Shutdown => *shutdown = true,
            }
        }
        fold(first, &mut pending, &mut acks, &mut snapshot, &mut shutdown);
        // Coalesce the rest of the group for one fsync interval — the
        // whole point of group commit: N appends, one fdatasync. A
        // barrier (Sync/Snapshot/Shutdown) closes the group early.
        // ubft-lint: allow(wall-clock-in-protocol) -- fsync worker pacing: group-commit
        // interval on a real disk is inherently wall-clock, never sim-visible
        let deadline = std::time::Instant::now() + interval;
        while !shutdown && snapshot.is_none() && acks.is_empty() {
            // ubft-lint: allow(wall-clock-in-protocol) -- remaining group-commit window
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(cmd) => fold(cmd, &mut pending, &mut acks, &mut snapshot, &mut shutdown),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        // One write + one fdatasync for the whole group.
        if !pending.is_empty() {
            if wal.write_all(&pending).is_err() {
                break 'outer;
            }
            let _ = wal.sync_data();
            pending.clear();
        }
        for ack in acks.drain(..) {
            let _ = ack.send(());
        }
        if let Some((upto, bytes, ack)) = snapshot {
            // Snapshot install: tmp + rename for atomicity, then rewrite
            // the WAL keeping only records the snapshot doesn't cover.
            let tmp = snap_path.with_extension("tmp");
            let mut framed = Vec::with_capacity(8 + bytes.len());
            framed.extend_from_slice(&upto.to_le_bytes());
            framed.extend_from_slice(&bytes);
            if std::fs::write(&tmp, &framed).is_ok() {
                let _ = std::fs::rename(&tmp, &snap_path);
            }
            drop(wal);
            let old = std::fs::read(&wal_path).unwrap_or_default();
            let (records, _) = parse_records(&old);
            let kept: Vec<(u64, Vec<u8>)> =
                records.into_iter().filter(|(s, _)| *s == RETAIN || *s >= upto).collect();
            let _ = std::fs::write(&wal_path, frame_all(&kept));
            wal = match std::fs::OpenOptions::new().create(true).append(true).open(&wal_path) {
                Ok(f) => f,
                Err(_) => return,
            };
            let _ = ack.send(());
        }
        if shutdown {
            break;
        }
    }
}

impl Persistence for FileSystemLog {
    fn durable(&self) -> bool {
        true
    }

    fn append(&mut self, slot: u64, rec: &[u8]) {
        let mut framed = Vec::with_capacity(FRAME_HEADER + 8 + rec.len());
        frame_record(&mut framed, slot, rec);
        self.appended += framed.len() as u64;
        let _ = self.tx.send(FsCmd::Append(framed));
    }

    fn sync(&mut self) {
        let (ack, done) = std::sync::mpsc::sync_channel(1);
        if self.tx.send(FsCmd::Sync(ack)).is_ok() {
            let _ = done.recv();
        }
    }

    fn put_snapshot(&mut self, upto: u64, bytes: &[u8]) {
        let (ack, done) = std::sync::mpsc::sync_channel(1);
        let cmd = FsCmd::Snapshot { upto, bytes: bytes.to_vec(), ack };
        if self.tx.send(cmd).is_ok() {
            let _ = done.recv();
        }
        self.appended = 0;
    }

    fn recover(&mut self) -> Recovered {
        self.recovered.take().unwrap_or_default()
    }

    fn wal_bytes(&self) -> u64 {
        self.appended
    }
}

impl Drop for FileSystemLog {
    fn drop(&mut self) {
        let _ = self.tx.send(FsCmd::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic LCG so the property tests stay seed-stable (no
    /// wall-clock, no OS randomness — the lint is right about that).
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
        fn below(&mut self, n: u64) -> u64 {
            self.next() % n.max(1)
        }
    }

    fn arbitrary_records(rng: &mut Lcg, n: usize) -> Vec<(u64, Vec<u8>)> {
        (0..n)
            .map(|_| {
                let slot = rng.below(1000);
                let len = rng.below(200) as usize;
                let payload: Vec<u8> = (0..len).map(|_| rng.next() as u8).collect();
                (slot, payload)
            })
            .collect()
    }

    #[test]
    fn framing_round_trips() {
        let mut rng = Lcg(42);
        for trial in 0..20 {
            let records = arbitrary_records(&mut rng, (trial % 7) + 1);
            let framed = frame_all(&records);
            let (parsed, torn) = parse_records(&framed);
            assert!(!torn);
            assert_eq!(parsed, records);
        }
    }

    #[test]
    fn truncation_anywhere_yields_a_clean_prefix() {
        // Chop the framed stream at *every* byte offset: the parse must
        // never panic, never invent a record, and must return exactly
        // the records fully contained in the prefix.
        let mut rng = Lcg(7);
        let records = arbitrary_records(&mut rng, 6);
        let framed = frame_all(&records);
        for cut in 0..=framed.len() {
            let (parsed, torn) = parse_records(&framed[..cut]);
            assert!(parsed.len() <= records.len());
            assert_eq!(parsed[..], records[..parsed.len()], "prefix property broke at {cut}");
            // Torn iff unparsed bytes remain past the clean prefix (a cut
            // exactly on a record boundary is a clean short log, not torn).
            assert_eq!(torn, cut != frame_all(&records[..parsed.len()]).len());
        }
    }

    #[test]
    fn corrupt_byte_in_last_record_drops_only_the_tail() {
        let mut rng = Lcg(9);
        let records = arbitrary_records(&mut rng, 4);
        let mut framed = frame_all(&records);
        let last = framed.len() - 1;
        framed[last] ^= 0xFF;
        let (parsed, torn) = parse_records(&framed);
        assert!(torn);
        assert_eq!(parsed, records[..3]);
    }

    #[test]
    fn sim_disk_survives_handle_drop_and_tears_cleanly() {
        let store = SimDiskStore::shared();
        {
            let mut p = SimDisk::new(2, store.clone());
            p.append(0, b"alpha");
            p.append(1, b"beta");
            p.append(RETAIN, b"view");
            p.append(2, b"gamma");
        } // handle dropped: the actor "crashed"
        let mut p = SimDisk::new(2, store.clone());
        let r = p.recover();
        assert!(!r.torn_tail);
        assert_eq!(r.wal.len(), 4);
        assert_eq!(r.wal[0], (0, b"alpha".to_vec()));
        assert_eq!(r.wal[2], (RETAIN, b"view".to_vec()));

        // Tear the tail: the last record (and only it) is dropped.
        assert!(store.lock().unwrap().tear_tail(2));
        let r = p.recover();
        assert!(r.torn_tail);
        assert_eq!(r.wal.len(), 3);
        assert_eq!(r.wal[2], (RETAIN, b"view".to_vec()));
    }

    #[test]
    fn sim_disk_snapshot_prunes_covered_records_keeps_retained() {
        let store = SimDiskStore::shared();
        let mut p = SimDisk::new(0, store);
        p.append(0, b"a");
        p.append(RETAIN, b"v");
        p.append(1, b"b");
        p.append(2, b"c");
        p.put_snapshot(2, b"SNAP");
        let r = p.recover();
        assert_eq!(r.snapshot, Some((2, b"SNAP".to_vec())));
        // Slot 0/1 covered by the snapshot; RETAIN and slot 2 survive.
        assert_eq!(r.wal, vec![(RETAIN, b"v".to_vec()), (2, b"c".to_vec())]);
    }

    #[test]
    fn in_memory_is_a_real_noop() {
        let mut p = InMemory;
        assert!(!p.durable());
        p.append(0, b"gone");
        p.put_snapshot(1, b"gone");
        let r = p.recover();
        assert!(r.snapshot.is_none() && r.wal.is_empty() && !r.torn_tail);
        assert_eq!(p.wal_bytes(), 0);
    }

    #[test]
    fn file_system_round_trips_across_reopen() {
        let dir = std::env::temp_dir().join(format!("ubft-persist-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut p = FileSystemLog::open(&dir, 1, 1_000_000).expect("open");
            assert!(p.recover().wal.is_empty());
            p.append(0, b"one");
            p.append(RETAIN, b"view");
            p.append(5, b"two");
            p.sync();
        } // drop: worker shuts down cleanly
        {
            let mut p = FileSystemLog::open(&dir, 1, 1_000_000).expect("reopen");
            let r = p.recover();
            assert!(!r.torn_tail);
            assert_eq!(
                r.wal,
                vec![(0, b"one".to_vec()), (RETAIN, b"view".to_vec()), (5, b"two".to_vec())]
            );
            p.put_snapshot(5, b"STATE");
            p.sync();
        }
        {
            let mut p = FileSystemLog::open(&dir, 1, 1_000_000).expect("third open");
            let r = p.recover();
            assert_eq!(r.snapshot, Some((5, b"STATE".to_vec())));
            assert_eq!(r.wal, vec![(RETAIN, b"view".to_vec()), (5, b"two".to_vec())]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_system_recovery_drops_a_torn_tail() {
        let dir = std::env::temp_dir().join(format!("ubft-torn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let mut p = FileSystemLog::open(&dir, 0, 1_000_000).expect("open");
            p.append(3, b"whole");
            p.append(4, b"torn-away");
            p.sync();
        }
        // Simulate power loss mid-write: chop the file mid-record.
        let wal = FileSystemLog::wal_path(&dir, 0);
        let bytes = std::fs::read(&wal).expect("wal written");
        std::fs::write(&wal, &bytes[..bytes.len() - 4]).unwrap();
        {
            let mut p = FileSystemLog::open(&dir, 0, 1_000_000).expect("reopen");
            let r = p.recover();
            assert!(r.torn_tail);
            assert_eq!(r.wal, vec![(3, b"whole".to_vec())]);
        }
        // The torn bytes were also scrubbed on disk: a third open is clean.
        {
            let mut p = FileSystemLog::open(&dir, 0, 1_000_000).expect("third open");
            let r = p.recover();
            assert!(!r.torn_tail);
            assert_eq!(r.wal, vec![(3, b"whole".to_vec())]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
