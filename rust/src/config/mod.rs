//! Deployment & protocol configuration.
//!
//! One [`Config`] describes a full deployment: cluster sizes, the CTBcast
//! tail `t`, the consensus window, timeouts, and the discrete-event
//! simulator's calibrated latency model ([`LatencyModel`]). Configs can be
//! loaded from simple `key = value` files (`examples/*.conf`) — serde is
//! unavailable offline, so parsing is hand-rolled.

use crate::smr::{PersistMode, ReadMode};
use crate::{Nanos, MICRO, MILLI};

/// Calibrated latency constants for the discrete-event simulator.
///
/// Base numbers are chosen so that the *unreplicated* RPC and the *Mu*
/// baseline land on the paper's measured values (Fig 7/8); everything else
/// is then a prediction of the model (see README.md).
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// One-way latency of a one-sided RDMA WRITE posting a message into a
    /// remote circular buffer (wire + PCIe + NIC processing), excluding
    /// the size-dependent part.
    pub p2p_base: Nanos,
    /// Extra nanoseconds per byte on the wire (100 Gbps ≈ 0.08 ns/B).
    pub per_byte: f64,
    /// Exponential jitter mean added to every network op.
    pub jitter_mean: Nanos,
    /// RTT of a one-sided RDMA READ of a (small) register replica.
    pub rdma_read: Nanos,
    /// One-way latency of a one-sided RDMA WRITE to a memory node,
    /// including the PCIe-fence READ that §6.1 issues behind it.
    pub rdma_write: Nanos,
    /// Local processing per delivered message (poll loop, copies, glue) —
    /// the paper's "Other" category in Fig 9.
    pub proc_overhead: Nanos,
    /// Ed25519 signature generation (paper's testbed: EdDSA via dalek).
    pub sign: Nanos,
    /// Ed25519 signature verification.
    pub verify: Nanos,
    /// HMAC create/verify (BLAKE3 in the paper: ≈100 ns).
    pub hmac: Nanos,
    /// SGX enclave crossing (paper §7.4 measured 7–12.5 µs; mean used by
    /// the emulated USIG).
    pub sgx_call: Nanos,
    /// Per-32B-block hashing cost (fingerprints, checksums).
    pub hash_per_block: Nanos,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            p2p_base: 900,
            per_byte: 0.08,
            jitter_mean: 60,
            rdma_read: 1_900,
            rdma_write: 2_200,
            proc_overhead: 150,
            sign: 11_000,
            verify: 33_000,
            hmac: 100,
            sgx_call: 9_500,
            hash_per_block: 15,
        }
    }
}

impl LatencyModel {
    /// One-way message latency for a payload of `bytes`.
    pub fn msg(&self, bytes: usize) -> Nanos {
        self.p2p_base + (bytes as f64 * self.per_byte) as Nanos
    }

    /// Hashing cost of `bytes` (checksums/fingerprints).
    pub fn hash_cost(&self, bytes: usize) -> Nanos {
        self.hash_per_block * ((bytes as u64 + 31) / 32).max(1)
    }
}

/// Which signature backend the deployment uses (see [`crate::crypto::KeyStore`]).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SigBackend {
    /// Real from-scratch Ed25519 (real-mode runs, examples).
    Ed25519,
    /// HMAC-based simulation backend; the DES charges Ed25519 latency.
    Sim,
}

/// Full deployment + protocol configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of compute replicas, `n = 2f + 1`.
    pub n: usize,
    /// Number of tolerated Byzantine replicas.
    pub f: usize,
    /// Number of memory nodes, `2 f_m + 1`.
    pub m: usize,
    /// Tolerated memory-node crashes.
    pub fm: usize,
    /// CTBcast tail parameter `t` (paper default 128).
    pub tail: usize,
    /// Consensus sliding-window size (paper evaluation: 256).
    pub window: usize,
    /// Maximum request payload bytes (sizes the p2p ring slots).
    pub max_req: usize,
    /// Maximum requests per consensus slot (adaptive batching; 1 = the
    /// paper's one-request-per-slot shape, the default).
    pub max_batch_reqs: usize,
    /// Maximum summed request payload bytes per batch. The first request
    /// of a batch always fits, so an oversized request stays proposable.
    pub max_batch_bytes: usize,
    /// Proposed-but-undecided slots the leader keeps in flight (the §9
    /// consensus pipeline, generalized). 0 = unbounded (the window is
    /// the only limit — the seed's behaviour). Small values (2–4) make
    /// the request queue accumulate so batches actually fill under load.
    pub max_inflight_slots: usize, // ubft-lint: allow(config-knob-coverage) -- 0 = unbounded
    /// δ — the known post-GST communication bound (register cooldown).
    pub delta: Nanos,
    /// Fast-path timeout before a slot falls back to the slow path.
    pub fastpath_timeout: Nanos,
    /// Progress timeout before a replica seals the view.
    pub viewchange_timeout: Nanos,
    /// TBcast retransmission interval.
    pub retransmit_every: Nanos,
    /// Force the slow path (used by slow-path benchmarks: Fig 8-10).
    pub slow_path_always: bool, // ubft-lint: allow(config-knob-coverage) -- both values valid
    /// Speculative execution: apply a slot's batch when its PREPARE is
    /// delivered (against an undo-logged service state, replies withheld)
    /// and promote the speculation in constant time at decide, taking
    /// application execution off the decide critical path. Off by
    /// default — the seed's apply-at-decide behaviour.
    pub speculation: bool, // ubft-lint: allow(config-knob-coverage) -- both values valid
    /// Hot-path buffer pool: wire frames, decoded payloads, and digest
    /// scratch buffers draw from a size-classed per-replica freelist and
    /// recycle instead of hitting the allocator per message. On by
    /// default; `pool = off` is the escape hatch restoring the seed's
    /// plain-allocation behaviour byte-for-byte (encodings are identical
    /// either way — pooling only changes backing memory).
    pub pool: bool, // ubft-lint: allow(config-knob-coverage) -- both values valid
    /// Pool size classes (bytes, ascending). Empty = the built-in
    /// [`crate::util::pool::DEFAULT_CLASSES`].
    pub pool_classes: Vec<usize>,
    /// Cap on idle bytes the pool retains (bounded-memory story, §7).
    pub pool_cap_bytes: usize, // ubft-lint: allow(config-knob-coverage) -- any cap; 0 retains nothing
    /// How clients route `ReadOnly`-classified requests (the typed
    /// `Service` read lane). Default: everything through consensus.
    pub read_mode: ReadMode, // ubft-lint: allow(config-knob-coverage) -- closed enum; parse rejects unknowns
    /// Model-checking mode (`ubft check`): replicas additionally keep the
    /// bounded per-slot applied-digest and CTBcast delivery logs the
    /// `testing::invariants` oracle cross-checks. Off by default — the
    /// logs cost memory and are useless outside the checker.
    pub mc: bool, // ubft-lint: allow(config-knob-coverage) -- both values valid
    /// Mutation-testing hook for the checker's self-validation: names one
    /// deliberately re-broken historical defense (see `ubft::mc`
    /// module docs for the catalog). `None` (the default, spelled
    /// `mc_mutation = none` in config files) runs the real protocol;
    /// anything else is for `ubft check` self-tests ONLY.
    pub mc_mutation: Option<String>, // ubft-lint: allow(config-knob-coverage) -- free-form mutation name; unknown names are inert
    /// How replicas persist consensus state across crash-restarts:
    /// `memory` (no durability, the seed behaviour), `sim-disk`
    /// (deterministic in-sim store, required for restart fault
    /// injection), or `file` (real WAL + snapshot files under
    /// [`Config::persist_dir`] with async group-fsync).
    pub persistence: PersistMode, // ubft-lint: allow(config-knob-coverage) -- closed enum; parse rejects unknowns
    /// Directory for `file`-mode WAL/snapshot files (one
    /// `wal-<node>.log` + `snap-<node>.bin` pair per replica).
    pub persist_dir: String, // ubft-lint: allow(config-knob-coverage) -- free-form path; deploy validates non-empty for file mode
    /// Group-fsync interval for `file` mode: the fsync worker batches
    /// WAL appends and syncs at most once per interval, keeping
    /// durability cost off the decide critical path.
    pub persist_fsync_interval_ns: Nanos,
    /// 2PC participant lock lease: a staged transaction whose commit or
    /// abort has not been decided within this long is aborted through
    /// consensus by the surviving participants (coordinator-crash lock
    /// leak defense; see `ubft::shard`).
    pub tx_lease_ns: Nanos,
    /// Signature backend.
    pub sig_backend: SigBackend, // ubft-lint: allow(config-knob-coverage) -- closed enum; parse rejects unknowns
    /// DES latency model.
    pub lat: LatencyModel,
    /// PRNG seed for the deployment.
    pub seed: u64, // ubft-lint: allow(config-knob-coverage) -- any seed is valid
}

impl Default for Config {
    fn default() -> Self {
        Config {
            n: 3,
            f: 1,
            m: 3,
            fm: 1,
            tail: 128,
            window: 256,
            max_req: 8192,
            max_batch_reqs: 1,
            max_batch_bytes: 64 * 1024,
            max_inflight_slots: 0,
            delta: 10 * MICRO,
            fastpath_timeout: 120 * MICRO,
            viewchange_timeout: 2 * MILLI,
            retransmit_every: 500 * MICRO,
            slow_path_always: false,
            speculation: false,
            pool: true,
            pool_classes: Vec::new(),
            pool_cap_bytes: crate::util::pool::DEFAULT_CAP_BYTES,
            read_mode: ReadMode::Consensus,
            mc: false,
            mc_mutation: None,
            persistence: PersistMode::InMemory,
            persist_dir: String::new(),
            persist_fsync_interval_ns: 100 * MICRO,
            tx_lease_ns: 50 * MILLI,
            sig_backend: SigBackend::Sim,
            lat: LatencyModel::default(),
            seed: 0xDEADBEEF,
        }
    }
}

impl Config {
    /// A quorum of replicas (f + 1).
    pub fn quorum(&self) -> usize {
        self.f + 1
    }

    /// Memory-node write/read quorum (f_m + 1).
    pub fn mem_quorum(&self) -> usize {
        self.fm + 1
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<(), String> {
        if self.n != 2 * self.f + 1 {
            return Err(format!("n={} must equal 2f+1 (f={})", self.n, self.f));
        }
        if self.m < 2 * self.fm + 1 {
            return Err(format!("m={} must be at least 2fm+1 (fm={})", self.m, self.fm));
        }
        if self.tail < 4 {
            return Err("tail must be >= 4".into());
        }
        if self.window == 0 {
            return Err("window must be > 0".into());
        }
        if self.max_batch_reqs == 0 {
            return Err("max_batch_reqs must be >= 1".into());
        }
        if self.max_batch_bytes == 0 {
            return Err("max_batch_bytes must be >= 1".into());
        }
        if self.max_batch_reqs > self.window {
            // A batch rides in one slot; capping it at the window keeps
            // the per-window request (and memory) bound within window×
            // of the unbatched accounting (§7).
            return Err(format!(
                "max_batch_reqs = {} must not exceed window = {}",
                self.max_batch_reqs, self.window
            ));
        }
        if self.max_req == 0 {
            return Err("max_req must be >= 1".into());
        }
        if self.delta == 0 || self.fastpath_timeout == 0 {
            return Err("delta / fastpath_timeout must be > 0".into());
        }
        if self.viewchange_timeout == 0 || self.retransmit_every == 0 {
            return Err("viewchange_timeout / retransmit_every must be > 0".into());
        }
        if self.pool_classes.first() == Some(&0)
            || self.pool_classes.windows(2).any(|w| w[0] >= w[1])
        {
            return Err("pool_classes must be nonzero and strictly ascending".into());
        }
        if !self.lat.per_byte.is_finite() || self.lat.per_byte < 0.0 {
            return Err("lat.per_byte must be finite and non-negative".into());
        }
        if self.persist_fsync_interval_ns == 0 {
            return Err("persist_fsync_interval_ns must be > 0".into());
        }
        if self.tx_lease_ns == 0 {
            return Err("tx_lease_ns must be > 0".into());
        }
        Ok(())
    }

    /// Parse `key = value` lines; `#` starts a comment. Unknown keys error.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut c = Config::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let k = k.trim();
            let v = v.trim();
            let u = |v: &str| v.parse::<u64>().map_err(|e| format!("line {}: {e}", lineno + 1));
            match k {
                "n" => c.n = u(v)? as usize,
                "f" => c.f = u(v)? as usize,
                "m" => c.m = u(v)? as usize,
                "fm" => c.fm = u(v)? as usize,
                "tail" => c.tail = u(v)? as usize,
                "window" => c.window = u(v)? as usize,
                "max_req" => c.max_req = u(v)? as usize,
                "max_batch_reqs" => c.max_batch_reqs = u(v)? as usize,
                "max_batch_bytes" => c.max_batch_bytes = u(v)? as usize,
                "max_inflight_slots" => c.max_inflight_slots = u(v)? as usize,
                "delta_ns" => c.delta = u(v)?,
                "fastpath_timeout_ns" => c.fastpath_timeout = u(v)?,
                "viewchange_timeout_ns" => c.viewchange_timeout = u(v)?,
                "retransmit_every_ns" => c.retransmit_every = u(v)?,
                "slow_path_always" => c.slow_path_always = v == "true" || v == "1",
                "speculation" => c.speculation = v == "true" || v == "1",
                "pool" => c.pool = v == "true" || v == "1" || v == "on",
                "pool_classes" => {
                    c.pool_classes = v
                        .split(',')
                        .map(|s| s.trim().parse::<usize>())
                        .collect::<Result<_, _>>()
                        .map_err(|_| format!("line {}: bad pool_classes {v}", lineno + 1))?;
                }
                "pool_cap_bytes" => c.pool_cap_bytes = u(v)? as usize,
                "read_mode" => {
                    c.read_mode = match v {
                        "consensus" => ReadMode::Consensus,
                        "direct" => ReadMode::Direct,
                        "linearizable" => ReadMode::Linearizable,
                        _ => return Err(format!("line {}: unknown read_mode {v}", lineno + 1)),
                    }
                }
                "mc" => c.mc = v == "true" || v == "1",
                "mc_mutation" => {
                    c.mc_mutation = if v == "none" { None } else { Some(v.to_string()) }
                }
                "persistence" => {
                    c.persistence = match v {
                        "memory" => PersistMode::InMemory,
                        "sim-disk" => PersistMode::SimDisk,
                        "file" => PersistMode::FileSystem,
                        _ => return Err(format!("line {}: unknown persistence {v}", lineno + 1)),
                    }
                }
                "persist_dir" => c.persist_dir = v.to_string(),
                "persist_fsync_interval_ns" => c.persist_fsync_interval_ns = u(v)?,
                "tx_lease_ns" => c.tx_lease_ns = u(v)?,
                "sig_backend" => {
                    c.sig_backend = match v {
                        "ed25519" => SigBackend::Ed25519,
                        "sim" => SigBackend::Sim,
                        _ => return Err(format!("line {}: unknown sig_backend {v}", lineno + 1)),
                    }
                }
                "seed" => c.seed = u(v)?,
                "lat.p2p_base" => c.lat.p2p_base = u(v)?,
                "lat.per_byte" => {
                    c.lat.per_byte =
                        v.parse::<f64>().map_err(|e| format!("line {}: {e}", lineno + 1))?
                }
                "lat.jitter_mean" => c.lat.jitter_mean = u(v)?,
                "lat.rdma_read" => c.lat.rdma_read = u(v)?,
                "lat.rdma_write" => c.lat.rdma_write = u(v)?,
                "lat.proc_overhead" => c.lat.proc_overhead = u(v)?,
                "lat.sign" => c.lat.sign = u(v)?,
                "lat.verify" => c.lat.verify = u(v)?,
                "lat.hmac" => c.lat.hmac = u(v)?,
                "lat.sgx_call" => c.lat.sgx_call = u(v)?,
                "lat.hash_per_block" => c.lat.hash_per_block = u(v)?,
                _ => return Err(format!("line {}: unknown key {k}", lineno + 1)),
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parse_overrides() {
        let c = Config::parse(
            "n = 5\nf = 2\ntail = 64 # comment\nslow_path_always = true\nlat.sign = 12000\n",
        )
        .unwrap();
        assert_eq!(c.n, 5);
        assert_eq!(c.f, 2);
        assert_eq!(c.tail, 64);
        assert!(c.slow_path_always);
        assert_eq!(c.lat.sign, 12_000);
    }

    #[test]
    fn parse_rejects_inconsistent() {
        assert!(Config::parse("n = 4\n").is_err()); // 4 != 2f+1
        assert!(Config::parse("bogus = 3\n").is_err());
    }

    #[test]
    fn batch_knobs_parse_and_validate() {
        let c = Config::parse(
            "max_batch_reqs = 32\nmax_batch_bytes = 4096\nmax_inflight_slots = 2\n",
        )
        .unwrap();
        assert_eq!(c.max_batch_reqs, 32);
        assert_eq!(c.max_batch_bytes, 4096);
        assert_eq!(c.max_inflight_slots, 2);
        assert!(Config::parse("max_batch_reqs = 0\n").is_err());
        assert!(Config::parse("max_batch_bytes = 0\n").is_err());
        // Batches are capped at the consensus window.
        assert!(Config::parse("window = 16\nmax_batch_reqs = 17\n").is_err());
        assert!(Config::parse("window = 16\nmax_batch_reqs = 16\n").is_ok());
    }

    #[test]
    fn speculation_parses_and_defaults_off() {
        assert!(!Config::default().speculation);
        assert!(Config::parse("speculation = true\n").unwrap().speculation);
        assert!(Config::parse("speculation = 1\n").unwrap().speculation);
        assert!(!Config::parse("speculation = false\n").unwrap().speculation);
    }

    #[test]
    fn pool_parses_and_defaults_on() {
        let d = Config::default();
        assert!(d.pool);
        assert!(d.pool_classes.is_empty());
        assert_eq!(d.pool_cap_bytes, crate::util::pool::DEFAULT_CAP_BYTES);
        assert!(!Config::parse("pool = off\n").unwrap().pool);
        assert!(!Config::parse("pool = false\n").unwrap().pool);
        assert!(Config::parse("pool = on\n").unwrap().pool);
        assert_eq!(
            Config::parse("pool_classes = 128, 512,2048\n").unwrap().pool_classes,
            vec![128, 512, 2048]
        );
        assert_eq!(
            Config::parse("pool_cap_bytes = 65536\n").unwrap().pool_cap_bytes,
            65536
        );
        assert!(Config::parse("pool_classes = 128,nope\n").is_err());
    }

    #[test]
    fn read_mode_parses_and_rejects_unknown() {
        assert_eq!(Config::parse("read_mode = direct\n").unwrap().read_mode, ReadMode::Direct);
        assert_eq!(
            Config::parse("read_mode = consensus\n").unwrap().read_mode,
            ReadMode::Consensus
        );
        assert_eq!(
            Config::parse("read_mode = linearizable\n").unwrap().read_mode,
            ReadMode::Linearizable
        );
        assert!(Config::parse("read_mode = sometimes\n").is_err());
    }

    #[test]
    fn mc_knobs_parse_and_default_off() {
        let d = Config::default();
        assert!(!d.mc);
        assert!(d.mc_mutation.is_none());
        assert!(Config::parse("mc = true\n").unwrap().mc);
        assert!(Config::parse("mc_mutation = none\n").unwrap().mc_mutation.is_none());
        assert_eq!(
            Config::parse("mc_mutation = stale-read-lane\n").unwrap().mc_mutation.as_deref(),
            Some("stale-read-lane")
        );
    }

    #[test]
    fn persistence_knobs_parse_and_default_off() {
        let d = Config::default();
        assert_eq!(d.persistence, PersistMode::InMemory);
        assert!(d.persist_dir.is_empty());
        assert_eq!(d.persist_fsync_interval_ns, 100 * MICRO);
        assert_eq!(d.tx_lease_ns, 50 * MILLI);
        assert_eq!(
            Config::parse("persistence = sim-disk\n").unwrap().persistence,
            PersistMode::SimDisk
        );
        assert_eq!(
            Config::parse("persistence = file\npersist_dir = /tmp/ubft\n")
                .unwrap()
                .persistence,
            PersistMode::FileSystem
        );
        assert_eq!(
            Config::parse("persist_dir = data/wal\n").unwrap().persist_dir,
            "data/wal"
        );
        assert_eq!(
            Config::parse("persist_fsync_interval_ns = 50000\n")
                .unwrap()
                .persist_fsync_interval_ns,
            50_000
        );
        assert_eq!(Config::parse("tx_lease_ns = 1000000\n").unwrap().tx_lease_ns, 1_000_000);
        assert!(Config::parse("persistence = floppy\n").is_err());
        assert!(Config::parse("persist_fsync_interval_ns = 0\n").is_err());
        assert!(Config::parse("tx_lease_ns = 0\n").is_err());
    }

    #[test]
    fn every_latency_knob_parses() {
        let c = Config::parse("lat.hash_per_block = 99\nlat.per_byte = 0.5\n").unwrap();
        assert_eq!(c.lat.hash_per_block, 99);
        assert!((c.lat.per_byte - 0.5).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_degenerate_knobs() {
        assert!(Config::parse("max_req = 0\n").is_err());
        assert!(Config::parse("delta_ns = 0\n").is_err());
        assert!(Config::parse("retransmit_every_ns = 0\n").is_err());
        assert!(Config::parse("pool_classes = 512,128\n").is_err());
        assert!(Config::parse("pool_classes = 0,128\n").is_err());
        assert!(Config::parse("lat.per_byte = -1\n").is_err());
    }

    #[test]
    fn latency_model_monotone_in_size() {
        let l = LatencyModel::default();
        assert!(l.msg(8192) > l.msg(8));
        assert!(l.hash_cost(1024) > l.hash_cost(32));
    }
}
