//! Self-check: the lint pass must run clean on this repository — every
//! pre-existing violation is either fixed or carries a justified waiver.
//! This is the same invariant `ci.sh` enforces, kept inside `cargo test`
//! so it cannot be skipped.

use std::path::Path;

fn repo_root() -> std::path::PathBuf {
    // rust/tools/lint → repo root is three levels up.
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = manifest.parent().unwrap().parent().unwrap().parent().unwrap();
    assert!(
        root.join("ci.sh").is_file() && root.join("rust/src/lib.rs").is_file(),
        "repo root not found from {}",
        manifest.display()
    );
    root.to_path_buf()
}

#[test]
fn repo_tree_is_lint_clean() {
    let report = ubft_lint::run(&repo_root()).expect("lint run");
    assert!(report.files > 50, "tree walk found only {} files", report.files);
    let rendered: Vec<String> = report
        .violations
        .iter()
        .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.lint, v.msg))
        .collect();
    assert!(rendered.is_empty(), "lint violations:\n{}", rendered.join("\n"));
}

#[test]
fn committed_unsafe_inventory_is_current() {
    let root = repo_root();
    let report = ubft_lint::run(&root).expect("lint run");
    let want = ubft_lint::render_inventory(&report.inventory);
    let have = std::fs::read_to_string(root.join(ubft_lint::INVENTORY_PATH))
        .expect("UNSAFE_INVENTORY.md is committed");
    assert_eq!(
        have, want,
        "UNSAFE_INVENTORY.md is stale — refresh with `cargo run -p ubft-lint -- --write-inventory`"
    );
}

#[test]
fn every_unsafe_site_is_inventoried_with_a_justification() {
    let report = ubft_lint::run(&repo_root()).expect("lint run");
    for e in &report.inventory {
        assert!(
            !e.safety.is_empty(),
            "{}:{} ({}) has no SAFETY justification",
            e.file,
            e.line,
            e.kind
        );
    }
}
