//! `wall-clock-in-protocol` for the `python/` tree.
//!
//! The python side compiles the repo's accelerator kernels and checks
//! them against references; like the Rust protocol code it must be
//! reproducible from explicit seeds. Wall-clock reads and the global
//! `random` module make compile fingerprints and test tensors vary per
//! host/process, so they are flagged everywhere except the harness
//! entry points (tests, the AOT CLI). Seeded NumPy generators
//! (`np.random.default_rng(seed)`) are the sanctioned idiom and are not
//! flagged — the bare-`random.` detector requires a word boundary, so
//! `np.random.` never matches.

use crate::lints::{Ctx, Violation};

/// Python files where wall-clock time and OS randomness are legitimate:
/// the test harness and the AOT compile CLI (an entry point that may
/// time compilation, not model/kernel code).
const PY_ALLOWED: &[&str] = &["python/tests/", "python/compile/aot.py"];

/// Call sites that read the host clock.
const PY_CLOCK: &[&str] = &[
    "time.time(",
    "time.sleep(",
    "time.perf_counter(",
    "time.monotonic(",
    "datetime.now(",
];

/// Lint one python source file. Same waiver syntax as the Rust lints,
/// with a `#` comment: `# ubft-lint: allow(wall-clock-in-protocol) -- why`.
pub fn lint_python_source(rel: &str, src: &str, ctx: &mut Ctx) {
    if PY_ALLOWED.iter().any(|m| rel.starts_with(m)) {
        return;
    }
    let raw: Vec<&str> = src.lines().collect();
    let code = strip_python(&raw);
    for l in 0..code.len() {
        let Some(what) = py_hit(&code[l]) else { continue };
        if py_waived(&raw, l) {
            ctx.waived += 1;
            continue;
        }
        ctx.violations.push(Violation {
            file: rel.to_string(),
            line: l + 1,
            lint: "wall-clock-in-protocol",
            msg: format!(
                "`{what}` in python model/kernel code: results must be \
                 reproducible from explicit seeds (np.random.default_rng(seed)), \
                 free of wall-clock dependence"
            ),
        });
    }
}

/// First wall-clock/nondeterminism pattern on a code line, if any.
fn py_hit(code: &str) -> Option<&'static str> {
    for p in PY_CLOCK {
        if code.contains(p) {
            return Some(p);
        }
    }
    let t = code.trim_start();
    if t.starts_with("import random") || t.starts_with("from random import") {
        return Some("import random");
    }
    // Bare `random.` — the stdlib global-state module. A preceding
    // identifier char or `.` means it's an attribute of something else
    // (`np.random.`, `jax.random.`) and is fine.
    let mut from = 0;
    while let Some(p) = code[from..].find("random.") {
        let at = from + p;
        let bounded = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_' || c == '.');
        if bounded {
            return Some("random.");
        }
        from = at + "random.".len();
    }
    None
}

/// Is line `l` (0-based) covered by a justified waiver comment?
fn py_waived(raw: &[&str], l: usize) -> bool {
    let needle = "ubft-lint: allow(wall-clock-in-protocol)";
    for k in l.saturating_sub(2)..=l {
        let line = raw[k];
        let Some(h) = line.find('#') else { continue };
        if let Some(p) = line[h..].find(needle) {
            if line[h + p + needle.len()..].contains("--") {
                return true;
            }
        }
    }
    false
}

/// Per-line code view: `#` comments stripped, string-literal contents
/// blanked (including triple-quoted blocks spanning lines), so text
/// mentioning `time.time(` never trips the lint.
fn strip_python(raw: &[&str]) -> Vec<String> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Normal,
        Str(char),
        Triple(char),
    }
    let mut st = St::Normal;
    let mut out = Vec::with_capacity(raw.len());
    for line in raw {
        let chars: Vec<char> = line.chars().collect();
        let mut code = String::with_capacity(line.len());
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match st {
                St::Normal => {
                    if c == '#' {
                        break; // rest of line is comment
                    } else if c == '"' || c == '\'' {
                        if chars.get(i + 1) == Some(&c) && chars.get(i + 2) == Some(&c) {
                            st = St::Triple(c);
                            code.push_str("   ");
                            i += 3;
                        } else {
                            st = St::Str(c);
                            code.push(c);
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                St::Str(q) => {
                    if c == '\\' {
                        code.push_str("  ");
                        i += 2;
                    } else if c == q {
                        st = St::Normal;
                        code.push(q);
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                St::Triple(q) => {
                    if c == q && chars.get(i + 1) == Some(&q) && chars.get(i + 2) == Some(&q) {
                        st = St::Normal;
                        code.push_str("   ");
                        i += 3;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
            }
        }
        // Single-quoted strings do not span lines in python.
        if matches!(st, St::Str(_)) {
            st = St::Normal;
        }
        out.push(code);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(rel: &str, src: &str) -> Vec<Violation> {
        let mut ctx = Ctx::new();
        lint_python_source(rel, src, &mut ctx);
        ctx.violations
    }

    #[test]
    fn flags_wall_clock_and_global_random() {
        let bad = "import random\nt0 = time.time()\nx = random.random()\n";
        let v = check("python/compile/model.py", bad);
        assert_eq!(v.len(), 3);
        assert!(v.iter().all(|x| x.lint == "wall-clock-in-protocol"));
        assert_eq!(v[1].line, 2);
    }

    #[test]
    fn seeded_numpy_and_entry_points_pass() {
        let good = "rng = np.random.default_rng(seed)\nx = jax.random.uniform(key)\n";
        assert!(check("python/compile/kernels/matmul.py", good).is_empty());
        // Harness entry points may read the clock.
        let timed = "t0 = time.perf_counter()\n";
        assert!(check("python/tests/test_kernel.py", timed).is_empty());
        assert!(check("python/compile/aot.py", timed).is_empty());
    }

    #[test]
    fn strings_comments_and_waivers_are_ignored() {
        let masked = "msg = \"call time.time() maybe\"  # or random.choice\n\
                      doc = '''\nrandom.seed is bad\n'''\n";
        assert!(check("python/compile/model.py", masked).is_empty());
        let waived = "# ubft-lint: allow(wall-clock-in-protocol) -- coarse progress log only\n\
                      t0 = time.time()\n";
        assert!(check("python/compile/model.py", waived).is_empty());
        let unjustified = "# ubft-lint: allow(wall-clock-in-protocol)\nt0 = time.time()\n";
        assert_eq!(check("python/compile/model.py", unjustified).len(), 1);
    }
}
