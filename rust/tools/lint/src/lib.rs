//! `ubft-lint` — repo-specific static analysis for the uBFT reproduction.
//!
//! The compiler cannot see the invariants this repo's guarantees rest on:
//! byte-identical same-seed sim runs, a (near-)allocation-free hot path,
//! audited `unsafe`, and a config whose every knob is actually reachable
//! from `.conf` files. This crate enforces them as five lints, run as a
//! blocking CI gate (`ci.sh`) via `cargo run -p ubft-lint` or `ubft lint`.
//!
//! | lint | guards |
//! |---|---|
//! | `nondet-iteration` | no `HashMap`/`HashSet` in protocol modules |
//! | `hot-path-alloc` | no direct allocation in `// ubft-lint: hot-path` fns |
//! | `wall-clock-in-protocol` | no `Instant`/`SystemTime`/`rand` outside the real driver |
//! | `unsafe-audit` | every `unsafe` carries `// SAFETY:`; inventory in `UNSAFE_INVENTORY.md` |
//! | `config-knob-coverage` | every `Config` field has parse/validate/doc coverage |
//!
//! See `rust/tools/lint/README.md` for the full catalog and the waiver
//! syntax (`// ubft-lint: allow(<lint>) -- <justification>`).

pub mod fix;
pub mod lints;
pub mod python;
pub mod scan;

use lints::{Ctx, InventoryEntry, Violation};
use std::path::{Path, PathBuf};

/// Directories (repo-relative) the tree walk lints.
const SCAN_DIRS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "rust/tools", "examples"];

/// Python directories scanned by the `wall-clock-in-protocol` lint
/// ([`python::lint_python_source`]).
const PY_SCAN_DIRS: &[&str] = &["python"];

/// The inventory file the `unsafe-audit` lint maintains, repo-relative.
pub const INVENTORY_PATH: &str = "UNSAFE_INVENTORY.md";

/// Result of linting a tree.
pub struct Report {
    pub violations: Vec<Violation>,
    pub inventory: Vec<InventoryEntry>,
    /// Waivers that suppressed a finding.
    pub waived: usize,
    /// Files scanned.
    pub files: usize,
}

/// Lint one file's source. `rel` uses forward slashes from the repo root
/// (e.g. `rust/src/consensus/mod.rs`) — module membership is decided from
/// it. Pure: fixture tests feed in-memory snippets.
pub fn lint_source(rel: &str, src: &str, ctx: &mut Ctx) {
    let s = scan::scan(src);
    lints::nondet_iteration(rel, &s, ctx);
    lints::hot_path_alloc(rel, &s, ctx);
    lints::wall_clock(rel, &s, ctx);
    lints::unsafe_audit(rel, &s, ctx);
    lints::config_knobs(rel, &s, ctx);
}

/// Lint the repo tree under `root`.
pub fn run(root: &Path) -> Result<Report, String> {
    let (rs_files, py_files) = collect_tree(root);
    let mut ctx = Ctx::new();
    let count = rs_files.len() + py_files.len();
    for path in rs_files {
        let (rel, src) = load(root, &path)?;
        lint_source(&rel, &src, &mut ctx);
    }
    for path in py_files {
        let (rel, src) = load(root, &path)?;
        python::lint_python_source(&rel, &src, &mut ctx);
    }
    ctx.violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    ctx.inventory.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Report {
        violations: ctx.violations,
        inventory: ctx.inventory,
        waived: ctx.waived,
        files: count,
    })
}

/// All lintable files under `root`, sorted: (.rs files, .py files).
fn collect_tree(root: &Path) -> (Vec<PathBuf>, Vec<PathBuf>) {
    let mut rs_files = Vec::new();
    for dir in SCAN_DIRS {
        collect_ext(&root.join(dir), "rs", &mut rs_files);
    }
    rs_files.sort();
    let mut py_files = Vec::new();
    for dir in PY_SCAN_DIRS {
        collect_ext(&root.join(dir), "py", &mut py_files);
    }
    py_files.sort();
    (rs_files, py_files)
}

fn load(root: &Path, path: &Path) -> Result<(String, String), String> {
    let rel = path
        .strip_prefix(root)
        .map_err(|e| e.to_string())?
        .to_string_lossy()
        .replace('\\', "/");
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Ok((rel, src))
}

fn collect_ext(dir: &Path, ext: &str, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            if p.file_name().is_some_and(|n| n == "target" || n == "__pycache__") {
                continue;
            }
            collect_ext(&p, ext, out);
        } else if p.extension().is_some_and(|x| x == ext) {
            out.push(p);
        }
    }
}

/// Apply [`fix::fix_source`] across the tree, writing changed files back.
/// Returns (files changed, rewrites, scaffolds).
pub fn run_fix(root: &Path) -> Result<(usize, usize, usize), String> {
    let (rs_files, _py) = collect_tree(root);
    let (mut changed, mut rewrites, mut scaffolds) = (0, 0, 0);
    for path in rs_files {
        let (rel, src) = load(root, &path)?;
        if let Some(out) = fix::fix_source(&rel, &src) {
            std::fs::write(&path, &out.fixed)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            println!(
                "ubft-lint: fixed {rel} ({} rewrites, {} waiver scaffolds)",
                out.rewrites, out.scaffolds
            );
            changed += 1;
            rewrites += out.rewrites;
            scaffolds += out.scaffolds;
        }
    }
    Ok((changed, rewrites, scaffolds))
}

/// Render the machine-readable `UNSAFE_INVENTORY.md`.
pub fn render_inventory(entries: &[InventoryEntry]) -> String {
    let mut out = String::from(
        "# Unsafe inventory\n\n\
         Generated by `cargo run -p ubft-lint -- --write-inventory`; `ci.sh`\n\
         regenerates it and fails on drift. Every entry is an `unsafe`\n\
         block, fn, or impl together with the first line of its\n\
         `// SAFETY:` justification (enforced by the `unsafe-audit` lint).\n\n\
         | location | kind | justification |\n\
         |---|---|---|\n",
    );
    for e in entries {
        out.push_str(&format!(
            "| `{}:{}` | {} | {} |\n",
            e.file,
            e.line,
            e.kind,
            e.safety.replace('|', "\\|")
        ));
    }
    out.push_str(&format!("\nTotal: {} unsafe sites.\n", entries.len()));
    out
}

/// Find the repo root by walking up from `start` looking for the
/// `ci.sh` + `rust/src/lib.rs` pair.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut d = start.to_path_buf();
    loop {
        if d.join("ci.sh").is_file() && d.join("rust/src/lib.rs").is_file() {
            return Some(d);
        }
        if !d.pop() {
            return None;
        }
    }
}

/// CLI entry point shared by the `ubft-lint` binary and `ubft lint`.
/// Returns the process exit code.
pub fn cli_main(args: &[String]) -> i32 {
    let mut root_arg: Option<PathBuf> = None;
    let mut write_inventory = false;
    let mut apply_fixes = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                let Some(p) = args.get(i) else {
                    eprintln!("ubft-lint: --root needs a path");
                    return 2;
                };
                root_arg = Some(PathBuf::from(p));
            }
            "--write-inventory" => write_inventory = true,
            "--fix" => apply_fixes = true,
            "--help" | "-h" => {
                println!(
                    "ubft-lint [--root PATH] [--write-inventory] [--fix]\n\
                     Repo-specific lints (see rust/tools/lint/README.md).\n\
                     --write-inventory  rewrite UNSAFE_INVENTORY.md from the tree\n\
                     --fix              apply HashMap/HashSet -> BTree rewrites and\n\
                                        insert FIXME waiver scaffolds, then re-lint"
                );
                return 0;
            }
            other => {
                eprintln!("ubft-lint: unknown argument {other}");
                return 2;
            }
        }
        i += 1;
    }
    let root = match root_arg.or_else(|| {
        std::env::current_dir().ok().and_then(|d| find_root(&d))
    }) {
        Some(r) => r,
        None => {
            eprintln!("ubft-lint: repo root not found (run inside the repo or pass --root)");
            return 2;
        }
    };
    if apply_fixes {
        match run_fix(&root) {
            Ok((changed, rewrites, scaffolds)) => println!(
                "ubft-lint: --fix changed {changed} files \
                 ({rewrites} BTree rewrites, {scaffolds} waiver scaffolds)"
            ),
            Err(e) => {
                eprintln!("ubft-lint: --fix: {e}");
                return 2;
            }
        }
        // Fall through: re-lint so the exit code reflects what remains.
    }
    let report = match run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("ubft-lint: {e}");
            return 2;
        }
    };
    for v in &report.violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.lint, v.msg);
    }
    if write_inventory {
        let path = root.join(INVENTORY_PATH);
        if let Err(e) = std::fs::write(&path, render_inventory(&report.inventory)) {
            eprintln!("ubft-lint: write {}: {e}", path.display());
            return 2;
        }
        println!(
            "ubft-lint: wrote {} ({} unsafe sites)",
            INVENTORY_PATH,
            report.inventory.len()
        );
    }
    println!(
        "ubft-lint: {} files, {} violations, {} waivers in effect",
        report.files,
        report.violations.len(),
        report.waived
    );
    if report.violations.is_empty() {
        0
    } else {
        1
    }
}

// ---------------------------------------------------------------------
// Fixture self-tests: one bad + one good snippet per lint, plus waiver
// syntax. These snippets are *strings*, so the lint run over this tool's
// own sources never sees them as code.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn check(rel: &str, src: &str) -> Vec<Violation> {
        let mut ctx = Ctx::new();
        lint_source(rel, src, &mut ctx);
        ctx.violations
    }

    fn names(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.lint).collect()
    }

    // ---- nondet-iteration ----

    // NB: fixtures avoid the `rust/src/consensus/mod.rs` path — that file
    // additionally requires every HOT_PATH_SEED fn annotation, which would
    // drown a single-lint fixture in seed violations.

    #[test]
    fn nondet_flags_hash_collections_in_protocol_modules() {
        let bad = "use std::collections::HashMap;\nstruct S { m: HashMap<u64, u8> }\n";
        let v = check("rust/src/tbcast/mod.rs", bad);
        assert_eq!(names(&v), ["nondet-iteration", "nondet-iteration"]);
        assert!(v[0].msg.contains("BTreeMap"), "fix-it names BTreeMap: {}", v[0].msg);
        assert_eq!(v[1].line, 2);
    }

    #[test]
    fn nondet_good_btreemap_and_non_protocol_modules_pass() {
        let good = "use std::collections::BTreeMap;\nstruct S { m: BTreeMap<u64, u8> }\n";
        assert!(check("rust/src/tbcast/mod.rs", good).is_empty());
        // Same hash collection outside a protocol module: fine.
        let hashy = "use std::collections::HashMap;\nstruct S { m: HashMap<u64, u8> }\n";
        assert!(check("rust/src/harness/fig10.rs", hashy).is_empty());
    }

    #[test]
    fn nondet_skips_test_modules_and_strings() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashSet;\n}\n\
                   const S: &str = \"HashMap\";\n";
        assert!(check("rust/src/ctbcast/mod.rs", src).is_empty());
    }

    #[test]
    fn nondet_waiver_suppresses_with_justification_only() {
        let waived = "// ubft-lint: allow(nondet-iteration) -- never iterated, keyed lookups only\n\
                      struct S { m: HashMap<u64, u8> }\n";
        assert!(check("rust/src/rpc/mod.rs", waived).is_empty());
        let unjustified = "// ubft-lint: allow(nondet-iteration)\n\
                           struct S { m: HashMap<u64, u8> }\n";
        assert_eq!(names(&check("rust/src/rpc/mod.rs", unjustified)), ["nondet-iteration"]);
    }

    // ---- hot-path-alloc ----

    #[test]
    fn hot_path_flags_direct_allocation() {
        let bad = "// ubft-lint: hot-path\nfn fast(&mut self) {\n    let v = data.to_vec();\n}\n";
        let v = check("rust/src/tbcast/mod.rs", bad);
        assert_eq!(names(&v), ["hot-path-alloc"]);
        assert_eq!(v[0].line, 3);
        assert!(v[0].msg.contains("util::pool"));
    }

    #[test]
    fn hot_path_good_pool_usage_and_unannotated_fns_pass() {
        let good = "// ubft-lint: hot-path\nfn fast(&mut self) {\n    let v = self.pool.take_vec(64);\n}\n";
        assert!(check("rust/src/tbcast/mod.rs", good).is_empty());
        // Allocation in a function not on the hot path: fine.
        let cold = "fn slow(&mut self) {\n    let v = data.to_vec();\n}\n";
        assert!(check("rust/src/tbcast/mod.rs", cold).is_empty());
    }

    #[test]
    fn hot_path_waiver_and_lookalike_idents() {
        let waived = "// ubft-lint: hot-path\nfn fast(&mut self) {\n    \
                      let p = arc.clone(); // ubft-lint: allow(hot-path-alloc) -- Arc refcount bump, no allocation\n}\n";
        assert!(check("rust/src/tbcast/mod.rs", waived).is_empty());
        // `.cloned()` and `clone_request_in(` are not `.clone(`.
        let lookalike = "// ubft-lint: hot-path\nfn fast(&mut self) {\n    \
                         let a = it.cloned();\n    self.clone_request_in(r);\n}\n";
        assert!(check("rust/src/tbcast/mod.rs", lookalike).is_empty());
    }

    #[test]
    fn hot_path_seed_fns_must_be_annotated_in_consensus() {
        // A consensus/mod.rs missing every seed annotation: one violation
        // per seed function name.
        let src = "fn decide(&mut self) {}\n";
        let v = check("rust/src/consensus/mod.rs", src);
        assert_eq!(v.iter().filter(|x| x.lint == "hot-path-alloc").count(),
                   lints::HOT_PATH_SEED.len());
        // `decide` is anchored at its definition line.
        let d = v.iter().find(|x| x.msg.contains("`decide`")).unwrap();
        assert_eq!(d.line, 1);
    }

    // ---- wall-clock-in-protocol ----

    #[test]
    fn wall_clock_flags_instant_in_protocol() {
        let bad = "fn f() { let t = std::time::Instant::now(); }\n";
        let v = check("rust/src/ctbcast/mod.rs", bad);
        assert_eq!(names(&v), ["wall-clock-in-protocol"]);
        let sleepy = "fn f() { std::thread::sleep(d); }\n";
        assert_eq!(names(&check("rust/src/deploy/mod.rs", sleepy)), ["wall-clock-in-protocol"]);
    }

    #[test]
    fn wall_clock_good_env_now_and_allowed_files_pass() {
        let good = "fn f(env: &mut dyn Env) { let t = env.now(); }\n";
        assert!(check("rust/src/ctbcast/mod.rs", good).is_empty());
        let real = "fn f() { let t = std::time::Instant::now(); }\n";
        assert!(check("rust/src/sim/real.rs", real).is_empty());
        assert!(check("rust/src/harness/fig7.rs", real).is_empty());
        assert!(check("rust/tests/it_deploy.rs", real).is_empty());
    }

    #[test]
    fn wall_clock_waiver() {
        let waived = "// ubft-lint: allow(wall-clock-in-protocol) -- real-mode wait helper, not protocol logic\n\
                      fn f() { let t = std::time::Instant::now(); }\n";
        assert!(check("rust/src/deploy/mod.rs", waived).is_empty());
    }

    // ---- unsafe-audit ----

    #[test]
    fn unsafe_audit_flags_missing_safety_comment() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let v = check("rust/src/util/mod.rs", bad);
        assert_eq!(names(&v), ["unsafe-audit"]);
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn unsafe_audit_good_safety_comment_passes_and_inventories() {
        let good = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n";
        let mut ctx = Ctx::new();
        lint_source("rust/src/util/mod.rs", good, &mut ctx);
        assert!(ctx.violations.is_empty());
        assert_eq!(ctx.inventory.len(), 1);
        assert_eq!(ctx.inventory[0].kind, "block");
        assert_eq!(ctx.inventory[0].safety, "caller guarantees p is valid.");
    }

    #[test]
    fn unsafe_audit_classifies_impls_and_fns() {
        let src = "// SAFETY: handle is never shared.\nunsafe impl Send for X {}\n\
                   // SAFETY: contract inherited from GlobalAlloc.\nunsafe fn alloc() {}\n";
        let mut ctx = Ctx::new();
        lint_source("rust/src/util/mod.rs", src, &mut ctx);
        assert!(ctx.violations.is_empty());
        let kinds: Vec<&str> = ctx.inventory.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, ["impl", "fn"]);
    }

    // ---- config-knob-coverage ----

    /// A minimal config file shape the lint accepts.
    const CONFIG_OK: &str = "\
pub struct LatencyModel {
    /// Base latency.
    pub p2p_base: u64,
}
pub struct Config {
    /// Replica count.
    pub n: usize,
    pub seed: u64, // ubft-lint: allow(config-knob-coverage) -- any seed is valid; no constraint to check
}
impl Config {
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 { return Err(\"n\".into()); }
        Ok(())
    }
    pub fn parse(text: &str) -> Result<Config, String> {
        match k {
            \"n\" => c.n = u(v)? as usize,
            \"seed\" => c.seed = u(v)?,
            \"lat.p2p_base\" => c.lat.p2p_base = u(v)?,
            _ => {}
        }
    }
}
";

    #[test]
    fn config_good_shape_passes() {
        let mut v = check("rust/src/config/mod.rs", CONFIG_OK);
        // `seed` has no doc comment in the fixture — that one finding is
        // expected; everything else is covered.
        v.retain(|x| !x.msg.contains("doc comment"));
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn config_flags_missing_parse_validate_and_doc() {
        let bad = "\
pub struct Config {
    pub n: usize,
}
impl Config {
    pub fn validate(&self) -> Result<(), String> { Ok(()) }
    pub fn parse(text: &str) -> Result<Config, String> { }
}
";
        let v = check("rust/src/config/mod.rs", bad);
        let msgs: Vec<&str> = v.iter().map(|x| x.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("no `\"n\"` arm")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("never checked")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("doc comment")), "{msgs:?}");
        // The lint only applies to the config module.
        assert!(check("rust/src/smr/mod.rs", bad).is_empty());
    }

    // ---- scanner corners ----

    #[test]
    fn scanner_handles_lifetimes_chars_and_raw_strings() {
        let src = "fn f<'a>(x: &'a str) -> char {\n    let c = 'x';\n    let s = r#\"HashMap \"quoted\"\"#;\n    let h = \"HashSet\";\n    c\n}\n";
        let s = scan::scan(src);
        // Literal contents are blanked out of the code view…
        assert!(!s.code.join("\n").contains("HashMap"));
        assert!(!s.code.join("\n").contains("HashSet"));
        // …while lifetimes survive as code.
        assert!(s.code[0].contains("'a"));
    }

    #[test]
    fn inventory_renders_sorted_table() {
        let entries = vec![lints::InventoryEntry {
            file: "rust/src/x.rs".into(),
            line: 3,
            kind: "block",
            safety: "bounds checked above.".into(),
        }];
        let md = render_inventory(&entries);
        assert!(md.contains("| `rust/src/x.rs:3` | block | bounds checked above. |"));
        assert!(md.contains("Total: 1 unsafe sites."));
    }
}
