//! `ubft-lint` binary: blocking repo lint (see `../README.md`).

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(ubft_lint::cli_main(&args));
}
