//! `ubft-lint --fix`: mechanical rewrites for fixable findings.
//!
//! Two fix classes, matching what can be repaired without judgment:
//!
//! * **`nondet-iteration`** — rewrite `HashMap` → `BTreeMap` and
//!   `HashSet` → `BTreeSet` at the flagged *code* positions (never
//!   inside strings or comments), `use` lines included. This is the
//!   lint's own fix-it, applied.
//! * **`hot-path-alloc` / `wall-clock-in-protocol`** — insert a waiver
//!   scaffold directly above the flagged line:
//!   `// ubft-lint: allow(<lint>) -- FIXME: justify this waiver or fix
//!   the finding`. The scaffold suppresses the finding (it carries a
//!   `--` justification) but leaves a greppable `FIXME`, so review —
//!   not the linter — decides whether the waiver stays. `unsafe-audit`
//!   and `config-knob-coverage` findings need real code and are never
//!   auto-fixed.
//!
//! Fixes are computed from the same scanner views the lints use, so a
//! `HashMap` inside a string literal is never rewritten. When the raw
//! line disagrees with the code view about how often the word occurs
//! (e.g. an extra mention in a trailing comment), the rewrite is
//! skipped for that line — `--fix` must never touch prose.

use crate::lints::Ctx;
use crate::scan::{self, find_word};

pub struct FixOutcome {
    pub fixed: String,
    /// `Hash* → BTree*` word rewrites applied.
    pub rewrites: usize,
    /// Waiver scaffold lines inserted.
    pub scaffolds: usize,
}

/// Compute the fixed text for one file, or `None` when nothing fixable
/// was found. Pure — callers decide whether to write the result back.
pub fn fix_source(rel: &str, src: &str) -> Option<FixOutcome> {
    let mut ctx = Ctx::new();
    crate::lint_source(rel, src, &mut ctx);
    if ctx.violations.is_empty() {
        return None;
    }
    let s = scan::scan(src);
    let mut lines: Vec<String> = s.raw.clone();
    let mut rewrites = 0;
    let mut scaffolds: Vec<(usize, &'static str)> = Vec::new();
    for v in &ctx.violations {
        let l = v.line - 1;
        match v.lint {
            "nondet-iteration" => {
                for (from, to) in [("HashMap", "BTreeMap"), ("HashSet", "BTreeSet")] {
                    // One violation is emitted per word per line; the
                    // message names the word, so only rewrite that one.
                    if v.msg.starts_with(from) {
                        rewrites += replace_word_in_code(&mut lines[l], &s.code[l], from, to);
                    }
                }
            }
            "hot-path-alloc" | "wall-clock-in-protocol" => {
                if !rel.ends_with(".py")
                    && !scaffolds.iter().any(|&(at, lint)| at == l && lint == v.lint)
                {
                    scaffolds.push((l, v.lint));
                }
            }
            _ => {}
        }
    }
    // Insert scaffolds bottom-up so earlier indices stay valid.
    scaffolds.sort_by(|a, b| b.cmp(a));
    let inserted = scaffolds.len();
    for (l, lint) in scaffolds {
        let indent: String = lines[l].chars().take_while(|c| c.is_whitespace()).collect();
        lines.insert(
            l,
            format!(
                "{indent}// ubft-lint: allow({lint}) -- FIXME: justify this \
                 waiver or fix the finding"
            ),
        );
    }
    let mut fixed = lines.join("\n");
    if src.ends_with('\n') {
        fixed.push('\n');
    }
    if fixed == src {
        return None;
    }
    Some(FixOutcome { fixed, rewrites, scaffolds: inserted })
}

/// Word-boundary replace of `from` with `to` in `raw`, but only when the
/// scanner's code view agrees every occurrence is code: if the raw line
/// holds more occurrences than the code view (the extras are in a string
/// or comment), the line is left untouched. Returns replacements made.
fn replace_word_in_code(raw: &mut String, code: &str, from: &str, to: &str) -> usize {
    let in_code = count_word(code, from);
    if in_code == 0 || count_word(raw, from) != in_code {
        return 0;
    }
    let mut out = String::with_capacity(raw.len() + 8);
    let mut cur = raw.as_str();
    let mut n = 0;
    while let Some(p) = find_word(cur, from) {
        out.push_str(&cur[..p]);
        out.push_str(to);
        cur = &cur[p + from.len()..];
        n += 1;
    }
    out.push_str(cur);
    *raw = out;
    n
}

fn count_word(line: &str, word: &str) -> usize {
    let mut n = 0;
    let mut cur = line;
    while let Some(p) = find_word(cur, word) {
        n += 1;
        cur = &cur[p + word.len()..];
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn relint(rel: &str, src: &str) -> Vec<&'static str> {
        let mut ctx = Ctx::new();
        crate::lint_source(rel, src, &mut ctx);
        ctx.violations.iter().map(|v| v.lint).collect()
    }

    #[test]
    fn rewrites_hash_collections_and_round_trips_clean() {
        let bad = "use std::collections::{HashMap, HashSet};\n\
                   struct S {\n    m: HashMap<u64, u8>,\n    s: HashSet<u64>,\n}\n";
        let out = fix_source("rust/src/tbcast/mod.rs", bad).expect("fixable");
        assert_eq!(out.rewrites, 4);
        assert!(out.fixed.contains("use std::collections::{BTreeMap, BTreeSet};"));
        assert!(out.fixed.contains("m: BTreeMap<u64, u8>"));
        // Round trip: the fixed source lints clean and re-fixing is a no-op.
        assert!(relint("rust/src/tbcast/mod.rs", &out.fixed).is_empty());
        assert!(fix_source("rust/src/tbcast/mod.rs", &out.fixed).is_none());
    }

    #[test]
    fn never_rewrites_strings_or_comments() {
        let tricky = "struct S { m: HashMap<u64, u8> } // docs mention HashMap\n";
        let out = fix_source("rust/src/rpc/mod.rs", tricky);
        // Raw count (2) disagrees with code count (1): line left alone,
        // and since nothing else is fixable there is no outcome.
        assert!(out.is_none(), "comment mention must block the rewrite");
        let stringy = "const HINT: &str = \"use HashMap here\";\n\
                       struct S { m: HashMap<u64, u8> }\n";
        let fixed = fix_source("rust/src/rpc/mod.rs", stringy).expect("fixable");
        assert!(fixed.fixed.contains("\"use HashMap here\""), "string must survive");
        assert!(fixed.fixed.contains("m: BTreeMap<u64, u8>"));
    }

    #[test]
    fn scaffolds_waivers_for_wall_clock_and_hot_path() {
        let bad = "fn f() {\n    let t = std::time::Instant::now();\n}\n";
        let out = fix_source("rust/src/smr/mod.rs", bad).expect("fixable");
        assert_eq!(out.scaffolds, 1);
        assert!(out
            .fixed
            .contains("    // ubft-lint: allow(wall-clock-in-protocol) -- FIXME:"));
        // Scaffolded source is lint-clean (FIXME review is human work now)
        // and idempotent under a second --fix.
        assert!(relint("rust/src/smr/mod.rs", &out.fixed).is_empty());
        assert!(fix_source("rust/src/smr/mod.rs", &out.fixed).is_none());

        let hot = "// ubft-lint: hot-path\nfn fast(&mut self) {\n    let v = x.to_vec();\n}\n";
        let out = fix_source("rust/src/tbcast/mod.rs", hot).expect("fixable");
        assert_eq!(out.scaffolds, 1);
        assert!(relint("rust/src/tbcast/mod.rs", &out.fixed).is_empty());
    }

    #[test]
    fn unfixable_lints_produce_no_outcome() {
        let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert!(fix_source("rust/src/util/mod.rs", bad).is_none());
    }
}
