//! A token-level Rust source scanner.
//!
//! The lints only need line-granular facts: "does this line of *code*
//! mention `HashMap`", "what comment text sits on or above line N", "is
//! this line inside a `#[cfg(test)]` item". A full AST is overkill for
//! that — and `syn` is unavailable offline — so this module hand-rolls
//! the one hard part: classifying every character as code, comment, or
//! literal. String/char literal *contents* are blanked out of the code
//! view (so `"HashMap"` in a message never trips a lint) and comments are
//! collected per line (so waivers and `SAFETY:` annotations are visible).

/// One source file, split into per-line views.
pub struct Scanned {
    /// Original lines, verbatim (string literals intact — the config lint
    /// matches parse keys against these).
    pub raw: Vec<String>,
    /// Code view: comments stripped, string/char literal contents blanked
    /// to spaces (the delimiting quotes are kept so literals still occupy
    /// a token position).
    pub code: Vec<String>,
    /// Comment text per line (both `//` and `/* */` forms, doc comments
    /// included — a `///` doc line appears here starting with `/`).
    pub comments: Vec<String>,
    /// Lines inside a `#[cfg(test)]` item (the attribute line itself and
    /// the whole brace-matched body). Most lints skip these.
    pub masked: Vec<bool>,
}

enum St {
    Normal,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Scan a source file into its per-line views and mask `#[cfg(test)]`
/// items.
pub fn scan(src: &str) -> Scanned {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut raw_lines: Vec<String> = src.lines().map(|l| l.to_string()).collect();
    if raw_lines.is_empty() {
        raw_lines.push(String::new());
    }
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut cur_code = String::new();
    let mut cur_com = String::new();
    let mut st = St::Normal;
    // Whether the previous code char continues an identifier (distinguishes
    // the raw-string sigil `r"` from an identifier ending in `r`).
    let mut prev_ident = false;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Normal;
            }
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_com));
            prev_ident = false;
            i += 1;
            continue;
        }
        match st {
            St::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur_code.push('"');
                    st = St::Str;
                    prev_ident = false;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    if let Some((hashes, consumed)) = raw_str_open(&chars, i) {
                        for _ in 0..consumed {
                            cur_code.push(' ');
                        }
                        st = St::RawStr(hashes);
                        i += consumed;
                    } else {
                        cur_code.push(c);
                        prev_ident = true;
                        i += 1;
                    }
                } else if c == '\'' {
                    i = scan_quote(&chars, i, &mut cur_code);
                    prev_ident = false;
                } else {
                    cur_code.push(c);
                    prev_ident = c.is_alphanumeric() || c == '_';
                    i += 1;
                }
            }
            St::LineComment => {
                cur_com.push(c);
                i += 1;
            }
            St::BlockComment(d) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(d + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if d == 1 { St::Normal } else { St::BlockComment(d - 1) };
                    i += 2;
                } else {
                    cur_com.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    cur_code.push(' ');
                    i += 1;
                    if i < n && chars[i] != '\n' {
                        cur_code.push(' ');
                        i += 1;
                    }
                } else if c == '"' {
                    cur_code.push('"');
                    st = St::Normal;
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && closes_raw(&chars, i, h) {
                    cur_code.push('"');
                    for _ in 0..h {
                        cur_code.push(' ');
                    }
                    st = St::Normal;
                    i += 1 + h as usize;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
        }
    }
    code.push(cur_code);
    comments.push(cur_com);
    // Align with `raw` (src.lines() drops a trailing newline's empty line).
    while code.len() > raw_lines.len() {
        raw_lines.push(String::new());
    }
    while code.len() < raw_lines.len() {
        code.push(String::new());
        comments.push(String::new());
    }
    let masked = vec![false; code.len()];
    let mut s = Scanned { raw: raw_lines, code, comments, masked };
    mask_cfg_test(&mut s);
    s
}

/// Does `r`/`b` at position `i` open a raw string (`r"`, `r#"`, `br"`,…)?
/// Returns (hash count, chars consumed including the opening quote).
fn raw_str_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        // Plain `b"…"` byte strings take the escape-aware Str path; only
        // `br…` raw forms are handled here.
        j += 1;
        if chars.get(j) != Some(&'r') {
            return None;
        }
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j - i + 1))
    } else {
        None // raw identifier (`r#match`) or plain ident char
    }
}

/// Does the `"` at position `i` close a raw string with `h` hashes?
fn closes_raw(chars: &[char], i: usize, h: u32) -> bool {
    (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Handle `'` in code: a char literal (contents blanked) or a lifetime
/// (passed through). Heuristic: `'\` or `'x'` is a literal; anything else
/// (`'a`, `'static`, `'_`) is a lifetime.
fn scan_quote(chars: &[char], i: usize, cur: &mut String) -> usize {
    let n = chars.len();
    let is_char = chars.get(i + 1) == Some(&'\\')
        || (chars.get(i + 2) == Some(&'\'') && chars.get(i + 1).is_some_and(|c| *c != '\''));
    cur.push('\'');
    let mut j = i + 1;
    if !is_char {
        return j; // lifetime: following ident chars are ordinary code
    }
    while j < n && chars[j] != '\n' {
        if chars[j] == '\\' {
            cur.push(' ');
            j += 1;
            if j < n && chars[j] != '\n' {
                cur.push(' ');
                j += 1;
            }
        } else if chars[j] == '\'' {
            cur.push('\'');
            j += 1;
            break;
        } else {
            cur.push(' ');
            j += 1;
        }
    }
    j
}

/// Mark every line belonging to a `#[cfg(test)]` item (attribute through
/// the end of the brace-matched body, or through the `;` for brace-less
/// items) as masked.
fn mask_cfg_test(s: &mut Scanned) {
    let n = s.code.len();
    let mut l = 0;
    while l < n {
        if !s.code[l].contains("#[cfg(test)]") {
            l += 1;
            continue;
        }
        let start = l;
        // Find where the item's body opens: the first `{` at or after the
        // attribute, skipping further attributes/blank lines. A `;` first
        // means a brace-less item (e.g. a `use`).
        let mut open = None;
        let mut j = l;
        while j < n {
            let line = &s.code[j];
            if let Some(pos) = line.find('{') {
                // A `;` before the `{` on an earlier or this line ends it.
                if let Some(sp) = line.find(';') {
                    if sp < pos {
                        open = None;
                        l = j + 1;
                        break;
                    }
                }
                open = Some((j, pos));
                break;
            }
            if line.contains(';') {
                open = None;
                l = j + 1;
                break;
            }
            j += 1;
        }
        let Some((open_line, open_pos)) = open else {
            for m in s.masked.iter_mut().take(l.min(n)).skip(start) {
                *m = true;
            }
            if l <= start {
                l = start + 1; // unterminated item: don't loop forever
            }
            continue;
        };
        // Brace-match from the opening line.
        let mut depth = 0i64;
        let mut end = open_line;
        'outer: for k in open_line..n {
            let from = if k == open_line { open_pos } else { 0 };
            for ch in s.code[k][char_floor(&s.code[k], from)..].chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => {
                        depth -= 1;
                        if depth == 0 {
                            end = k;
                            break 'outer;
                        }
                    }
                    _ => {}
                }
            }
            end = k;
        }
        for m in s.masked.iter_mut().take(end + 1).skip(start) {
            *m = true;
        }
        l = end + 1;
    }
}

/// Clamp a byte offset to a char boundary (blanked literals are ASCII
/// spaces, but raw code may hold multi-byte chars before the offset).
fn char_floor(line: &str, byte: usize) -> usize {
    let mut b = byte.min(line.len());
    while b > 0 && !line.is_char_boundary(b) {
        b -= 1;
    }
    b
}

/// Brace-match the body of the item whose header is on `start` (the line
/// holding the opening `{`, e.g. a `fn` signature line). Returns the
/// inclusive end line.
pub fn item_end(s: &Scanned, start: usize) -> usize {
    let n = s.code.len();
    let mut depth = 0i64;
    let mut seen_open = false;
    for k in start..n {
        for ch in s.code[k].chars() {
            match ch {
                '{' => {
                    depth += 1;
                    seen_open = true;
                }
                '}' => {
                    depth -= 1;
                    if seen_open && depth == 0 {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    n - 1
}

/// Does `code` contain `word` as a whole identifier (not a substring of a
/// longer identifier)?
pub fn has_word(code: &str, word: &str) -> bool {
    find_word(code, word).is_some()
}

/// Byte offset of `word` as a whole identifier in `code`.
pub fn find_word(code: &str, word: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0
            || !code[..at].chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = at + word.len();
        let after_ok = after >= code.len()
            || !code[after..].chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + word.len();
    }
    None
}
