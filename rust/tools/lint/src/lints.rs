//! The five repo-specific lints. Catalog with rationale and waiver syntax:
//! `rust/tools/lint/README.md`.
//!
//! Waiver syntax (all lints except `unsafe-audit`, whose remedy — a
//! `// SAFETY:` comment — is always available):
//!
//! ```text
//! // ubft-lint: allow(<lint-name>) -- <justification>
//! ```
//!
//! on the flagged line or up to two lines above it. A waiver without a
//! `--` justification does not count.

use crate::scan::{find_word, has_word, item_end, Scanned};

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Repo-relative path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Lint name (kebab-case, as used in waivers).
    pub lint: &'static str,
    pub msg: String,
}

/// One `unsafe` site, for `UNSAFE_INVENTORY.md`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InventoryEntry {
    pub file: String,
    /// 1-based line of the `unsafe` keyword.
    pub line: usize,
    /// `impl`, `fn`, or `block`.
    pub kind: &'static str,
    /// First line of the `// SAFETY:` justification (empty if missing —
    /// which is itself a violation).
    pub safety: String,
}

/// Shared output accumulator for one file.
pub struct Ctx {
    pub violations: Vec<Violation>,
    pub inventory: Vec<InventoryEntry>,
    /// Waivers that suppressed a finding (reported in the summary so
    /// they stay visible).
    pub waived: usize,
}

impl Ctx {
    pub fn new() -> Ctx {
        Ctx { violations: Vec::new(), inventory: Vec::new(), waived: 0 }
    }
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx::new()
    }
}

/// Modules whose state can reach the wire or the decided log: hash-order
/// nondeterminism here breaks same-seed reproducibility.
const PROTOCOL_MODULES: &[&str] = &[
    "rust/src/consensus/",
    "rust/src/tbcast/",
    "rust/src/ctbcast/",
    "rust/src/shard/",
    "rust/src/rpc/",
    "rust/src/dsm/",
];

/// Files/dirs where wall-clock time and OS randomness are legitimate:
/// the real-thread driver, the CLI, harnesses, benches, tests, examples,
/// and this tool. Everything else must go through `Env::now`/`Env::rng`.
const WALL_CLOCK_ALLOWED: &[&str] = &[
    "rust/src/sim/real.rs",
    "rust/src/main.rs",
    "rust/src/harness/",
    "rust/benches/",
    "rust/tests/",
    "rust/tools/",
    "examples/",
];

/// Functions on the propose→speculate→certify→apply path. Each must carry
/// a `// ubft-lint: hot-path` annotation (so the path stays visible in the
/// source) and is then checked for direct allocations.
pub const HOT_PATH_SEED: &[&str] = &[
    "try_propose",
    "endorse",
    "try_speculate",
    "speculate",
    "decide",
    "try_apply",
    "promote_speculation",
    "is_fresh",
    "cache_reply",
    "take_carrier",
    "put_carrier",
    "recycle_batch",
    "clone_request_in",
];

/// Allocation expressions forbidden in hot-path functions (route through
/// `util::pool` instead, or waive with a justification).
const HOT_PATH_FORBIDDEN: &[&str] = &[
    "Vec::new(",
    "vec![",
    ".to_vec(",
    ".clone(",
    "format!",
    "Box::new(",
    "String::from(",
    "String::new(",
    ".to_string(",
    "::with_capacity(",
    ".to_owned(",
];

/// Is line `l` (0-based) covered by a justified waiver for `lint`?
fn waived(s: &Scanned, l: usize, lint: &str, ctx: &mut Ctx) -> bool {
    let needle = format!("ubft-lint: allow({lint})");
    for k in l.saturating_sub(2)..=l {
        if let Some(p) = s.comments[k].find(needle.as_str()) {
            if s.comments[k][p + needle.len()..].contains("--") {
                ctx.waived += 1;
                return true;
            }
        }
    }
    false
}

/// Lint 1 — `nondet-iteration`: no `HashMap`/`HashSet` in protocol
/// modules. Iteration order of std hash collections is randomized per
/// process (SipHash keys), so any iterated/drained hash collection in
/// replica state silently breaks byte-identical same-seed runs the moment
/// its order reaches the wire or the decided log. Declarations are
/// flagged outright — the deterministic fix is `BTreeMap`/`BTreeSet`.
pub fn nondet_iteration(rel: &str, s: &Scanned, ctx: &mut Ctx) {
    if !PROTOCOL_MODULES.iter().any(|m| rel.starts_with(m)) {
        return;
    }
    for l in 0..s.code.len() {
        if s.masked[l] {
            continue;
        }
        for word in ["HashMap", "HashSet"] {
            if has_word(&s.code[l], word) && !waived(s, l, "nondet-iteration", ctx) {
                let fix = if word == "HashMap" { "BTreeMap" } else { "BTreeSet" };
                ctx.violations.push(Violation {
                    file: rel.to_string(),
                    line: l + 1,
                    lint: "nondet-iteration",
                    msg: format!(
                        "{word} in protocol module (randomized iteration order): \
                         use {fix} for deterministic order"
                    ),
                });
            }
        }
    }
}

/// Lint 2 — `hot-path-alloc`: functions annotated `// ubft-lint: hot-path`
/// (plus the seed list in `consensus/mod.rs`, which must be annotated) may
/// not allocate directly — the static backstop to the dynamic
/// `UBFT_ALLOC_GATE` bench gate, which only exercises one bench shape.
pub fn hot_path_alloc(rel: &str, s: &Scanned, ctx: &mut Ctx) {
    let n = s.code.len();
    // Annotated functions: `// ubft-lint: hot-path` directly above (≤ 3
    // lines, to allow attributes between) a `fn` header.
    let mut hot: Vec<(usize, String)> = Vec::new(); // (header line, name)
    for l in 0..n {
        if !s.comments[l].contains("ubft-lint: hot-path") {
            continue;
        }
        for k in l..(l + 4).min(n) {
            if let Some(name) = fn_name(&s.code[k]) {
                hot.push((k, name));
                break;
            }
        }
    }
    if rel == "rust/src/consensus/mod.rs" {
        for seed in HOT_PATH_SEED {
            if hot.iter().any(|(_, name)| name == seed) {
                continue;
            }
            // Find the unannotated definition so the finding is anchored.
            let at = (0..n)
                .find(|&l| {
                    !s.masked[l] && s.code[l].contains(&format!("fn {seed}("))
                })
                .map(|l| l + 1)
                .unwrap_or(1);
            ctx.violations.push(Violation {
                file: rel.to_string(),
                line: at,
                lint: "hot-path-alloc",
                msg: format!(
                    "hot-path seed function `{seed}` must carry a \
                     `// ubft-lint: hot-path` annotation"
                ),
            });
        }
    }
    for (header, name) in hot {
        let end = item_end(s, header);
        for l in header..=end {
            if s.masked[l] {
                continue;
            }
            for pat in HOT_PATH_FORBIDDEN {
                if s.code[l].contains(pat) && !waived(s, l, "hot-path-alloc", ctx) {
                    ctx.violations.push(Violation {
                        file: rel.to_string(),
                        line: l + 1,
                        lint: "hot-path-alloc",
                        msg: format!(
                            "`{}` allocates in hot-path fn `{name}`: take buffers \
                             from util::pool instead",
                            pat.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
    }
}

/// Extract the function name from a `fn` header line, if any.
fn fn_name(code: &str) -> Option<String> {
    let p = find_word(code, "fn")?;
    let rest = code[p + 2..].trim_start();
    let end = rest.find(|c: char| !(c.is_alphanumeric() || c == '_'))?;
    if end == 0 {
        return None;
    }
    Some(rest[..end].to_string())
}

/// Lint 3 — `wall-clock-in-protocol`: `Instant`/`SystemTime`/
/// `thread::sleep`/`rand::` outside the real-mode driver and harness code
/// makes protocol behaviour depend on the host, which the deterministic
/// simulator cannot reproduce. Protocol code gets time and randomness
/// only through `Env::now` / `Env::rng`.
pub fn wall_clock(rel: &str, s: &Scanned, ctx: &mut Ctx) {
    if WALL_CLOCK_ALLOWED.iter().any(|m| rel.starts_with(m)) {
        return;
    }
    for l in 0..s.code.len() {
        if s.masked[l] {
            continue;
        }
        let code = &s.code[l];
        let hit = ["Instant", "SystemTime"].iter().find(|w| has_word(code, w)).copied()
            .or_else(|| ["thread::sleep", "rand::"].iter().find(|p| code.contains(*p)).copied());
        if let Some(what) = hit {
            if !waived(s, l, "wall-clock-in-protocol", ctx) {
                ctx.violations.push(Violation {
                    file: rel.to_string(),
                    line: l + 1,
                    lint: "wall-clock-in-protocol",
                    msg: format!(
                        "`{what}` outside the real-mode driver: protocol code must \
                         use Env::now / Env::rng so the sim stays deterministic"
                    ),
                });
            }
        }
    }
}

/// Lint 4 — `unsafe-audit`: every `unsafe` block/fn/impl must carry a
/// `// SAFETY:` comment — on the same line, or above it across a
/// contiguous run of comment/attribute/blank lines (so `#[cfg(...)]`
/// attributes between the comment and the `unsafe` don't break the
/// association). Also collects the machine-readable inventory committed
/// as `UNSAFE_INVENTORY.md`. Not waivable — the remedy is writing the
/// justification itself.
pub fn unsafe_audit(rel: &str, s: &Scanned, ctx: &mut Ctx) {
    for l in 0..s.code.len() {
        if !has_word(&s.code[l], "unsafe") {
            continue;
        }
        let kind = if s.code[l].contains("unsafe impl") {
            "impl"
        } else if s.code[l].contains("unsafe fn") {
            "fn"
        } else {
            "block"
        };
        let mut safety = safety_text(&s.comments[l]);
        let mut k = l;
        while safety.is_empty() && k > 0 {
            k -= 1;
            safety = safety_text(&s.comments[k]);
            if !safety.is_empty() {
                break;
            }
            let code = s.code[k].trim();
            if !(code.is_empty() || code.starts_with("#[")) {
                break; // a real code line ends the comment block
            }
        }
        if safety.is_empty() {
            ctx.violations.push(Violation {
                file: rel.to_string(),
                line: l + 1,
                lint: "unsafe-audit",
                msg: format!("unsafe {kind} without a `// SAFETY:` justification"),
            });
        }
        ctx.inventory.push(InventoryEntry {
            file: rel.to_string(),
            line: l + 1,
            kind,
            safety,
        });
    }
}

/// Text after `SAFETY:` in a comment line, if present.
fn safety_text(comment: &str) -> String {
    match comment.find("SAFETY:") {
        Some(p) => comment[p + "SAFETY:".len()..].trim().to_string(),
        None => String::new(),
    }
}

/// Lint 5 — `config-knob-coverage`: every `Config` field needs a parse
/// key, a `validate()` mention (or a justified waiver on the field), and
/// a doc comment; every `LatencyModel` field needs a `lat.*` parse key
/// and a doc comment. Catches the drift a fast-growing config accumulates
/// (e.g. a field added without a `parse()` arm is silently unsettable
/// from `.conf` files).
pub fn config_knobs(rel: &str, s: &Scanned, ctx: &mut Ctx) {
    if rel != "rust/src/config/mod.rs" {
        return;
    }
    let parse = fn_region(s, "parse");
    let validate = fn_region(s, "validate");
    let (Some(parse), Some(validate)) = (parse, validate) else {
        ctx.violations.push(Violation {
            file: rel.to_string(),
            line: 1,
            lint: "config-knob-coverage",
            msg: "Config::parse / Config::validate not found".to_string(),
        });
        return;
    };
    let parse_raw = s.raw[parse.0..=parse.1].join("\n");
    let validate_code = s.code[validate.0..=validate.1].join("\n");
    for (l, field) in struct_fields(s, "Config") {
        if !parse_raw.contains(&format!("\"{field}")) {
            ctx.violations.push(Violation {
                file: rel.to_string(),
                line: l + 1,
                lint: "config-knob-coverage",
                msg: format!("Config field `{field}` has no `\"{field}\"` arm in Config::parse"),
            });
        }
        if !has_word(&validate_code, &field) && !waived(s, l, "config-knob-coverage", ctx) {
            ctx.violations.push(Violation {
                file: rel.to_string(),
                line: l + 1,
                lint: "config-knob-coverage",
                msg: format!(
                    "Config field `{field}` is never checked in Config::validate \
                     (add a check or waive with a justification)"
                ),
            });
        }
        require_doc(rel, s, l, &field, ctx);
    }
    for (l, field) in struct_fields(s, "LatencyModel") {
        if !parse_raw.contains(&format!("\"lat.{field}\"")) {
            ctx.violations.push(Violation {
                file: rel.to_string(),
                line: l + 1,
                lint: "config-knob-coverage",
                msg: format!(
                    "LatencyModel field `{field}` has no `\"lat.{field}\"` arm in Config::parse"
                ),
            });
        }
        require_doc(rel, s, l, &field, ctx);
    }
}

fn require_doc(rel: &str, s: &Scanned, l: usize, field: &str, ctx: &mut Ctx) {
    let documented = l > 0 && s.comments[l - 1].trim_start().starts_with('/');
    if !documented {
        ctx.violations.push(Violation {
            file: rel.to_string(),
            line: l + 1,
            lint: "config-knob-coverage",
            msg: format!("config field `{field}` has no doc comment"),
        });
    }
}

/// Field names (with 0-based declaration lines) of `pub struct <name>`.
fn struct_fields(s: &Scanned, name: &str) -> Vec<(usize, String)> {
    let header = format!("struct {name} ");
    for l in 0..s.code.len() {
        if s.masked[l] || !s.code[l].contains(header.trim_end()) || !s.code[l].contains('{') {
            continue;
        }
        // Require an exact-word struct name (`Config`, not `ConfigX`).
        if !has_word(&s.code[l], name) {
            continue;
        }
        let end = item_end(s, l);
        let mut out = Vec::new();
        for k in (l + 1)..end {
            let t = s.code[k].trim();
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some(colon) = rest.find(':') {
                    let ident = rest[..colon].trim();
                    if !ident.is_empty()
                        && ident.chars().all(|c| c.is_alphanumeric() || c == '_')
                    {
                        out.push((k, ident.to_string()));
                    }
                }
            }
        }
        return out;
    }
    Vec::new()
}

/// (start, end) lines of `fn <name>(`, brace-matched.
fn fn_region(s: &Scanned, name: &str) -> Option<(usize, usize)> {
    let needle = format!("fn {name}(");
    for l in 0..s.code.len() {
        if !s.masked[l] && s.code[l].contains(needle.as_str()) {
            return Some((l, item_end(s, l)));
        }
    }
    None
}
