//! BFT-replicated financial order matching (the paper's Liquibook
//! application): a stream of limit orders is totally ordered by uBFT and
//! matched identically on every replica.
//!
//! ```sh
//! cargo run --release --example order_matching
//! ```

use ubft::apps::orderbook::{parse_fills, OrderWorkload};
use ubft::apps::OrderBookApp;
use ubft::config::Config;
use ubft::consensus::Replica;
use ubft::rpc::{Client, Workload};
use ubft::sim::Sim;
use ubft::smr::App;

/// Wrapper workload that counts fills from the execution reports.
struct CountingWorkload {
    inner: OrderWorkload,
    fills: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl Workload for CountingWorkload {
    fn next_request(&mut self, rng: &mut ubft::util::Rng) -> Vec<u8> {
        self.inner.next_request(rng)
    }
    fn check_response(&mut self, _req: &[u8], resp: &[u8]) -> bool {
        if let Some((_, fills)) = parse_fills(resp) {
            self.fills
                .fetch_add(fills.len() as u64, std::sync::atomic::Ordering::Relaxed);
            true
        } else {
            false
        }
    }
    fn name(&self) -> &'static str {
        "liquibook"
    }
}

fn main() {
    let cfg = Config::default();
    let mut sim = Sim::new(cfg.clone());
    for i in 0..cfg.n {
        sim.add_actor(Box::new(Replica::new(i, cfg.clone(), Box::new(OrderBookApp::new()))));
    }
    let fills = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let orders = 10_000;
    let client = Client::new(
        (0..cfg.n).collect(),
        cfg.quorum(),
        Box::new(CountingWorkload { inner: OrderWorkload::paper(), fills: fills.clone() }),
        orders,
    );
    let samples = client.samples_handle();
    let done = client.done_handle();
    sim.add_actor(Box::new(client));
    let mut horizon = ubft::SECOND;
    while done.lock().unwrap().is_none() && horizon <= 64 * ubft::SECOND {
        sim.run_until(horizon);
        horizon *= 2;
    }

    let mut s = samples.lock().unwrap();
    println!("BFT order matching: {} orders executed", s.len());
    println!("  fills generated : {}", fills.load(std::sync::atomic::Ordering::Relaxed));
    println!("  p50 / p90 / p99 : {:.2} / {:.2} / {:.2} µs",
        s.percentile(50.0) as f64 / 1000.0,
        s.percentile(90.0) as f64 / 1000.0,
        s.percentile(99.0) as f64 / 1000.0);

    // Replicas must hold identical books (state-machine safety).
    let digests: Vec<_> = (0..cfg.n)
        .map(|i| {
            let a = sim.actor_mut(i);
            let r = unsafe { &*(a as *const dyn ubft::env::Actor as *const Replica) };
            r.app().digest()
        })
        .collect();
    assert!(digests.windows(2).all(|w| w[0] == w[1]), "books diverged!");
    println!("  all {} replicas hold identical order books ✓", cfg.n);
}
