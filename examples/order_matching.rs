//! BFT-replicated financial order matching (the paper's Liquibook
//! application): a stream of limit orders is totally ordered by uBFT and
//! matched identically on every replica.
//!
//! ```sh
//! cargo run --release --example order_matching
//! ```

use ubft::apps::orderbook::{parse_fills, OrderWorkload};
use ubft::apps::OrderBookApp;
use ubft::config::Config;
use ubft::deploy::{Deployment, System};
use ubft::rpc::Workload;

/// Wrapper workload that counts fills from the execution reports.
struct CountingWorkload {
    inner: OrderWorkload,
    fills: std::sync::Arc<std::sync::atomic::AtomicU64>,
}

impl Workload for CountingWorkload {
    fn next_request(&mut self, rng: &mut ubft::util::Rng) -> Vec<u8> {
        self.inner.next_request(rng)
    }
    fn check_response(&mut self, _req: &[u8], resp: &[u8]) -> bool {
        if let Some((_, fills)) = parse_fills(resp) {
            self.fills
                .fetch_add(fills.len() as u64, std::sync::atomic::Ordering::Relaxed);
            true
        } else {
            false
        }
    }
    fn name(&self) -> &'static str {
        "liquibook"
    }
}

fn main() {
    let orders = 10_000;
    let fills = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mut cluster = Deployment::new(Config::default())
        .system(System::UbftFast)
        .app(|| Box::new(OrderBookApp::new()))
        .client(Box::new(CountingWorkload {
            inner: OrderWorkload::paper(),
            fills: fills.clone(),
        }))
        .requests(orders)
        .build()
        .expect("valid deployment");
    cluster.run_to_completion();

    let mut s = cluster.samples();
    println!("BFT order matching: {} orders executed", s.len());
    assert_eq!(cluster.mismatches(), 0, "malformed execution reports");
    println!("  fills generated : {}", fills.load(std::sync::atomic::Ordering::Relaxed));
    println!("  p50 / p90 / p99 : {:.2} / {:.2} / {:.2} µs",
        s.percentile(50.0) as f64 / 1000.0,
        s.percentile(90.0) as f64 / 1000.0,
        s.percentile(99.0) as f64 / 1000.0);

    // Replicas must hold identical books (state-machine safety).
    assert!(cluster.converged(), "books diverged!");
    println!("  all {} replicas hold identical order books ✓", cluster.config().n);
}
