//! Real-mode replicated KV store: three uBFT replicas on OS threads with
//! real (from-scratch) Ed25519, serving the paper's memcached workload —
//! then a live crash of one memory node to show fault tolerance.
//!
//! ```sh
//! cargo run --release --example kv_store
//! ```

use std::time::{Duration, Instant};
use ubft::apps::kv::KvWorkload;
use ubft::apps::KvApp;
use ubft::config::{Config, SigBackend};
use ubft::deploy::{Deployment, System};

fn run(requests: usize, crash_mem_node: bool) {
    let mut cfg = Config::default();
    cfg.sig_backend = SigBackend::Ed25519;
    // Real-thread timeouts are in wall-clock ns; widen them (channel
    // scheduling is far coarser than the simulated RDMA fabric).
    cfg.fastpath_timeout = 30 * ubft::MILLI;
    cfg.viewchange_timeout = 400 * ubft::MILLI;
    cfg.retransmit_every = 20 * ubft::MILLI;

    let mut cluster = Deployment::new(cfg)
        .system(System::UbftFast)
        .app(|| Box::new(KvApp::new()))
        .client(Box::new(KvWorkload::paper()))
        .requests(requests)
        .build_real()
        .expect("valid real-mode deployment");

    let t0 = Instant::now();
    cluster.start();
    if crash_mem_node {
        // Let some requests through, then "crash" one memory node to show
        // the register quorums absorb it (the paper's f_m tolerance).
        std::thread::sleep(Duration::from_millis(200));
        cluster.mem().crash(2);
        println!("  [crashed memory node 2 at t={:?} — majority quorums continue]", t0.elapsed());
    }
    if !cluster.wait(Duration::from_secs(180)) {
        println!("  [timed out]");
    }
    let wall = t0.elapsed();
    let mut s = cluster.samples();
    let stopped = cluster.stop();
    assert!(stopped.converged(), "replicas diverged");
    println!(
        "  {} requests in {:.2}s — p50 {:.0} µs, p99 {:.0} µs, {:.1} kops",
        s.len(),
        wall.as_secs_f64(),
        s.median() as f64 / 1000.0,
        s.percentile(99.0) as f64 / 1000.0,
        s.len() as f64 / wall.as_secs_f64() / 1000.0
    );
}

fn main() {
    println!("real-mode uBFT KV store (3 replicas, Ed25519, OS threads)");
    println!("fault-free run:");
    run(2_000, false);
    println!("with a memory-node crash mid-run:");
    run(2_000, true);
}
