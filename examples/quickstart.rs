//! Quickstart: replicate a memcached-style KV store with uBFT in the
//! deterministic simulator and print the latency profile.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ubft::apps::kv::KvWorkload;
use ubft::apps::KvApp;
use ubft::config::Config;
use ubft::consensus::Replica;
use ubft::rpc::Client;
use ubft::sim::Sim;

fn main() {
    // 1. Configuration: n = 2f+1 = 3 replicas, 2f_m+1 = 3 memory nodes,
    //    CTBcast tail t = 128, consensus window 256 (the paper's setup).
    let cfg = Config::default();
    cfg.validate().expect("valid config");

    // 2. Deploy replicas, each with its own application instance.
    let mut sim = Sim::new(cfg.clone());
    for i in 0..cfg.n {
        sim.add_actor(Box::new(Replica::new(i, cfg.clone(), Box::new(KvApp::new()))));
    }

    // 3. A closed-loop client running the paper's memcached mix
    //    (30% GET / 70% SET, 16 B keys, 32 B values).
    let client = Client::new(
        (0..cfg.n).collect(),
        cfg.quorum(), // wait for f+1 matching replies
        Box::new(KvWorkload::paper()),
        5_000,
    );
    let samples = client.samples_handle();
    sim.add_actor(Box::new(client));

    // 4. Run and report.
    sim.run_until(10 * ubft::SECOND);
    let mut s = samples.lock().unwrap();
    println!("uBFT-replicated memcached-style KV ({} requests):", s.len());
    for p in [50.0, 90.0, 99.0, 99.9] {
        println!("  p{p:<5} {:>8.2} µs", s.percentile(p) as f64 / 1000.0);
    }
    println!(
        "\nByzantine fault tolerance (f = {}) for ~{:.1} µs over an unreplicated server.",
        cfg.f,
        (s.median() as f64 - 2_950.0) / 1000.0
    );
}
