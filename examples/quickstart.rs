//! Quickstart: replicate a memcached-style KV store with uBFT in the
//! deterministic simulator and print the latency profile — then run the
//! same workload unreplicated to measure the true cost of BFT.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ubft::apps::kv::KvWorkload;
use ubft::apps::KvApp;
use ubft::config::Config;
use ubft::deploy::{Deployment, System};

/// Deploy `system` on the paper's default configuration (n = 2f+1 = 3
/// replicas, 2f_m+1 = 3 memory nodes, CTBcast tail t = 128), run the
/// paper's memcached mix (30% GET / 70% SET, 16 B keys, 32 B values) to
/// completion, and return the latency samples.
fn run(system: System, requests: usize) -> ubft::metrics::Samples {
    let mut cluster = Deployment::new(Config::default())
        .system(system)
        .app(|| Box::new(KvApp::new()))
        .client(Box::new(KvWorkload::paper()))
        .requests(requests)
        .build()
        .expect("valid deployment");
    cluster.run_to_completion();
    assert!(cluster.converged(), "replicas diverged");
    cluster.samples()
}

fn main() {
    let requests = 5_000;
    let mut replicated = run(System::UbftFast, requests);
    println!("uBFT-replicated memcached-style KV ({} requests):", replicated.len());
    for p in [50.0, 90.0, 99.0, 99.9] {
        println!("  p{p:<5} {:>8.2} µs", replicated.percentile(p) as f64 / 1000.0);
    }

    // The baseline is measured, not assumed: the same workload against a
    // single unreplicated server, deployed through the same builder.
    let mut unrepl = run(System::Unreplicated, requests);
    println!(
        "\nByzantine fault tolerance (f = {}) for ~{:.1} µs over an unreplicated server \
         (measured p50 {:.2} µs).",
        Config::default().f,
        (replicated.median() as f64 - unrepl.median() as f64) / 1000.0,
        unrepl.median() as f64 / 1000.0
    );
}
