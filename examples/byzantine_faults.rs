//! Byzantine fault demo: an equivocating CTBcast broadcaster tells two
//! different stories to two receivers — on both the fast path (LOCK /
//! LOCKED) and the slow path (validly signed conflicting SIGNED
//! messages). CTBcast's agreement property must hold: the correct
//! receivers never deliver different messages for the same identifier.
//!
//! ```sh
//! cargo run --release --example byzantine_faults
//! ```

use std::sync::{Arc, Mutex};
use ubft::byz::EquivocatingBroadcaster;
use ubft::config::Config;
use ubft::crypto::KeyStore;
use ubft::ctbcast::{CtbEndpoint, CtbOut};
use ubft::env::{Actor, Env, Event};
use ubft::sim::Sim;

/// Honest receiver running a real CTBcast endpoint.
struct Receiver {
    cfg: Config,
    ctb: Option<CtbEndpoint>,
    log: Arc<Mutex<Vec<(usize, usize, u64, Vec<u8>)>>>,
}

impl Actor for Receiver {
    fn on_start(&mut self, env: &mut dyn Env) {
        self.ctb = Some(CtbEndpoint::new(env.me(), &self.cfg, KeyStore::sim(self.cfg.seed)));
        env.set_timer(200 * ubft::MICRO, 1);
    }
    fn on_event(&mut self, env: &mut dyn Env, ev: Event) {
        let outs = match ev {
            Event::Recv { from, bytes } => self.ctb.as_mut().unwrap().on_recv(env, from, &bytes),
            Event::MemDone { ticket, result, .. } => {
                self.ctb.as_mut().unwrap().on_mem_done(env, ticket, result)
            }
            Event::Timer { token: 1 } => {
                self.ctb.as_mut().unwrap().on_retransmit(env);
                env.set_timer(200 * ubft::MICRO, 1);
                vec![]
            }
            Event::Timer { token } => self.ctb.as_mut().unwrap().on_timer(env, token),
        };
        for o in outs {
            match o {
                CtbOut::Deliver { bcaster, k, m } => {
                    self.log.lock().unwrap().push((env.me(), bcaster, k, m));
                }
                CtbOut::Byzantine { bcaster } => {
                    println!("  receiver {} PROVED broadcaster {} Byzantine (register conflict)",
                        env.me(), bcaster);
                }
                CtbOut::App { .. } => {}
            }
        }
    }
}

fn main() {
    let cfg = Config::default();
    let ks = KeyStore::sim(cfg.seed);
    let log = Arc::new(Mutex::new(Vec::new()));

    let mut sim = Sim::new(cfg.clone());
    // Node 0 is the Byzantine broadcaster: story A to node 1, story B to 2.
    sim.add_actor(Box::new(EquivocatingBroadcaster::new(
        0,
        ks,
        vec![1],
        vec![2],
        b"transfer $100 to alice".to_vec(),
        b"transfer $100 to mallory".to_vec(),
        true, // also attack the slow path with valid signatures
    )));
    sim.add_actor(Box::new(Receiver { cfg: cfg.clone(), ctb: None, log: log.clone() }));
    sim.add_actor(Box::new(Receiver { cfg: cfg.clone(), ctb: None, log: log.clone() }));
    sim.run_until(ubft::SECOND);

    let log = log.lock().unwrap();
    println!("\nequivocation attack on CTBcast identifier k=1:");
    for (me, b, k, m) in log.iter() {
        println!("  receiver {me} delivered ({b},{k}) = {:?}", String::from_utf8_lossy(m));
    }
    // Agreement: for (broadcaster 0, k=1), all deliveries identical.
    let values: Vec<&Vec<u8>> =
        log.iter().filter(|(_, b, k, _)| *b == 0 && *k == 1).map(|(_, _, _, m)| m).collect();
    let agree = values.windows(2).all(|w| w[0] == w[1]);
    assert!(agree, "AGREEMENT VIOLATED");
    if values.is_empty() {
        println!("  no receiver delivered — safe (tail-validity only binds correct broadcasters)");
    }
    println!("\nagreement holds: no two correct receivers accepted different stories ✓");
}
