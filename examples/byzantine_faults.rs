//! Byzantine fault demo through the deployment builder: replica 0 — the
//! view-0 leader — is replaced by an equivocating CTBcast broadcaster
//! that tells two different stories to the two correct replicas, on both
//! the fast path (LOCK / LOCKED) and the slow path (validly signed
//! conflicting SIGNED messages).
//!
//! CTBcast's agreement property (§2.2, Alg 1) must neutralize the attack:
//! the correct replicas never adopt conflicting messages, treat the
//! silent Byzantine leader like a crashed one, run a view change, and
//! serve the client from view 1 — state-machine safety and liveness both
//! hold with f = 1 actively malicious replica.
//!
//! ```sh
//! cargo run --release --example byzantine_faults
//! ```

use ubft::config::Config;
use ubft::deploy::{Deployment, FaultPlan, System};
use ubft::rpc::BytesWorkload;

fn main() {
    let cfg = Config::default();
    let requests = 30;

    // Replica 0 equivocates: story A to replica 1, story B to replica 2.
    let attack = FaultPlan::equivocate(
        0,
        vec![1],
        vec![2],
        b"transfer $100 to alice".to_vec(),
        b"transfer $100 to mallory".to_vec(),
    );

    let mut cluster = Deployment::new(cfg.clone())
        .system(System::UbftFast)
        .client(Box::new(BytesWorkload { size: 32, label: "noop" }))
        .requests(requests)
        .faults(attack)
        .build()
        .expect("valid Byzantine deployment");

    println!("equivocation attack: Byzantine replica(s) {:?} of n = {}", cluster.byz_ids(), cfg.n);
    let completed = cluster.run_to_completion();

    // Liveness: with f = 1 Byzantine, the two correct replicas must still
    // serve every request (after a view change away from the attacker).
    assert!(completed, "client starved by a single Byzantine replica");
    let mut s = cluster.samples();
    println!("client completed {}/{} requests (p50 {:.1} µs)", s.len(), requests,
        s.median() as f64 / 1000.0);

    // Safety: the correct replicas applied identical sequences.
    let digests = cluster.digests();
    println!("correct replica states (applied_upto, digest): {} entries", digests.len());
    assert!(cluster.converged(), "AGREEMENT VIOLATED: correct replicas diverged");

    // The survivors moved past the Byzantine leader's view.
    for &i in &[1usize, 2] {
        let p = cluster.probe(i).expect("correct replica probes");
        println!("  replica {i}: view {} applied {}", p.view, p.applied_upto);
        assert!(p.view >= 1, "replica {i} never left the Byzantine leader's view");
    }
    println!("\nagreement + progress hold under equivocation: attack neutralized ✓");
}
