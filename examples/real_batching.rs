//! Real-mode adaptive batching demo (the standing ROADMAP follow-up):
//! three uBFT replicas on OS threads with real Ed25519, driven by one
//! pipelined client, once with the seed's one-request-per-slot shape and
//! once with `.batch(..)` + `.slot_pipeline(..)` — printing the measured
//! batch occupancy at the leader so the amortization is visible on real
//! threads, not just under the DES.
//!
//! ```sh
//! cargo run --release --example real_batching
//! ```

use std::time::{Duration, Instant};
use ubft::apps::kv::KvWorkload;
use ubft::apps::KvApp;
use ubft::config::{Config, SigBackend};
use ubft::deploy::{Deployment, System};

/// One run; returns (p50 µs, kops, leader batch occupancy, max batch).
fn run(requests: usize, batch: usize, slots: usize) -> (f64, f64, f64, u64) {
    let mut cfg = Config::default();
    cfg.sig_backend = SigBackend::Ed25519;
    // Real-thread timeouts are in wall-clock ns; widen them (channel
    // scheduling is far coarser than the simulated RDMA fabric).
    cfg.fastpath_timeout = 30 * ubft::MILLI;
    cfg.viewchange_timeout = 400 * ubft::MILLI;
    cfg.retransmit_every = 20 * ubft::MILLI;

    let mut d = Deployment::new(cfg)
        .system(System::UbftFast)
        .app(|| Box::new(KvApp::new()))
        .client(Box::new(KvWorkload::paper()))
        .requests(requests)
        // A deep client pipeline is what lets the leader's queue
        // accumulate into batches at all.
        .pipeline(16);
    if batch > 1 {
        d = d.batch(batch, 64 * 1024).slot_pipeline(slots);
    }
    let mut cluster = d.build_real().expect("valid real-mode deployment");

    let t0 = Instant::now();
    cluster.start();
    if !cluster.wait(Duration::from_secs(180)) {
        cluster.stop();
        panic!("real-mode batching run timed out after 180s ({requests} requests)");
    }
    let wall = t0.elapsed();
    let mut s = cluster.samples();
    let stopped = cluster.stop();
    assert!(stopped.converged(), "replicas diverged");
    // The view-0 leader is replica 0: read its proposer-side batch stats.
    let stats = stopped.replica(0).expect("replica 0 introspects").stats.clone();
    (
        s.median() as f64 / 1000.0,
        s.len() as f64 / wall.as_secs_f64() / 1000.0,
        stats.batch_occupancy(),
        stats.max_batch,
    )
}

fn main() {
    let requests = std::env::var("UBFT_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(600);
    println!("real-mode adaptive batching (3 replicas, Ed25519, OS threads)");
    println!("unbatched (seed shape, 16 requests in flight):");
    let (p50, kops, occ, max) = run(requests, 1, 0);
    println!("  p50 {p50:.0} µs, {kops:.1} kops, occupancy {occ:.2} (max batch {max})");
    println!("batch(16, 64 KiB) + slot_pipeline(2):");
    let (p50, kops, occ, max) = run(requests, 16, 2);
    println!("  p50 {p50:.0} µs, {kops:.1} kops, occupancy {occ:.2} (max batch {max})");
    assert!(occ >= 1.0, "leader never proposed");
    println!("done.");
}
