//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! * **L1** — the Pallas matmul kernel (authored in
//!   `python/compile/kernels/matmul.py`, validated vs the jnp oracle);
//! * **L2** — the JAX MLP forward graph calling it, AOT-lowered to
//!   `artifacts/mlp.hlo.txt` at build time (`make artifacts`);
//! * **L3** — three uBFT replicas on OS threads with real Ed25519 load
//!   the artifact via PJRT and serve BFT-replicated inference requests,
//!   with the client accepting f+1 matching replies.
//!
//! The whole deployment is described through the [`ubft::deploy`] builder.
//! Prints latency/throughput, verifies every response against a native
//! re-computation, and checks replica state digests agree — proving all
//! layers compose.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_tensor_service
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};
use ubft::apps::tensor::{TensorApp, TensorWorkload, Weights};
use ubft::config::{Config, SigBackend};
use ubft::deploy::{Deployment, System};
use ubft::runtime::{shapes, Runtime};

fn main() {
    let dir = Runtime::artifacts_dir();
    let path = format!("{dir}/mlp.hlo.txt");
    if !std::path::Path::new(&path).exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // L3 loads the L2/L1 artifact once; Python is not running.
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let module = Arc::new(rt.load(&path).expect("compile mlp.hlo.txt"));
    println!("loaded {} (AOT JAX+Pallas → HLO → PJRT)", module.path);

    let mut cfg = Config::default();
    cfg.sig_backend = SigBackend::Ed25519;
    cfg.fastpath_timeout = 30 * ubft::MILLI;
    cfg.viewchange_timeout = 400 * ubft::MILLI;
    cfg.retransmit_every = 20 * ubft::MILLI;
    let n = cfg.n;
    let seed = 2024;
    let requests = 500;

    let app_module = module.clone();
    let mut cluster = Deployment::new(cfg)
        .system(System::UbftFast)
        .app(move || Box::new(TensorApp::new(app_module.clone(), seed)))
        .client(Box::new(TensorWorkload))
        .requests(requests)
        .build_real()
        .expect("valid real-mode deployment");

    println!("serving {requests} BFT-replicated inference requests ({n} replicas, Ed25519)…");
    let t0 = Instant::now();
    cluster.start();
    if !cluster.wait(Duration::from_secs(300)) {
        eprintln!("timed out");
    }
    let wall = t0.elapsed();
    let mut s = cluster.samples();
    let stopped = cluster.stop();

    println!(
        "\ncompleted {} / {requests} requests in {:.2}s",
        s.len(),
        wall.as_secs_f64()
    );
    println!(
        "  latency  p50 {:.0} µs | p90 {:.0} µs | p99 {:.0} µs",
        s.median() as f64 / 1000.0,
        s.percentile(90.0) as f64 / 1000.0,
        s.percentile(99.0) as f64 / 1000.0
    );
    println!(
        "  throughput {:.0} req/s (batched MLP {}×{}→{}→{})",
        s.len() as f64 / wall.as_secs_f64(),
        shapes::MLP_BATCH,
        shapes::MLP_IN,
        shapes::MLP_HIDDEN,
        shapes::MLP_OUT
    );

    // Replica agreement: identical applied counts and state digests.
    let digests = stopped.digests();
    println!("  replica states: {digests:?}");
    assert!(stopped.converged(), "replicas diverged!");
    println!("  all replicas agree ✓");

    // Cross-check one inference against a native recomputation.
    let weights = Weights::deterministic(seed);
    let x = vec![0.25f32; shapes::MLP_BATCH * shapes::MLP_IN];
    let via_hlo = module
        .mlp_forward(&x, &weights.w1, &weights.b1, &weights.w2, &weights.b2)
        .unwrap();
    let mut h = vec![0f32; shapes::MLP_HIDDEN];
    for j in 0..shapes::MLP_HIDDEN {
        let mut acc = weights.b1[j];
        for k in 0..shapes::MLP_IN {
            acc += x[k] * weights.w1[k * shapes::MLP_HIDDEN + j];
        }
        h[j] = acc.max(0.0);
    }
    let mut want0 = vec![0f32; shapes::MLP_OUT];
    for j in 0..shapes::MLP_OUT {
        let mut acc = weights.b2[j];
        for k in 0..shapes::MLP_HIDDEN {
            acc += h[k] * weights.w2[k * shapes::MLP_OUT + j];
        }
        want0[j] = acc;
    }
    for j in 0..shapes::MLP_OUT {
        assert!((via_hlo[j] - want0[j]).abs() < 1e-4);
    }
    println!("  HLO numerics match native recomputation ✓\nE2E: all three layers compose.");
}
