"""L2: the JAX compute graphs the Rust coordinator executes via PJRT.

Three exported functions (shapes fixed at AOT time; see ``aot.py`` and
``rust/src/runtime/mod.rs::shapes``):

* ``fingerprint_batch`` — bulk message fingerprints (calls the L1 Pallas
  fingerprint kernel);
* ``batch_verify`` — fingerprints a batch and compares against expected
  digests, returning a 0/1 mask (the tail-verification path used at
  checkpoint/summary time);
* ``mlp_forward`` — the tensor service's two-layer MLP (both layers run
  the L1 Pallas matmul kernel).
"""

import jax.numpy as jnp

from .kernels.fingerprint import fingerprint
from .kernels.matmul import matmul_bias


def fingerprint_batch(msgs):
    """(B, W) uint32 -> (B,) uint32 fingerprints."""
    return (fingerprint(msgs),)


def batch_verify(msgs, expected):
    """(B, W) uint32, (B,) uint32 -> (B,) uint32 mask (1 = digest match)."""
    fps = fingerprint(msgs)
    return ((fps == expected).astype(jnp.uint32),)


def mlp_forward(x, w1, b1, w2, b2):
    """Two-layer MLP: relu(x@w1+b1) @ w2 + b2, all via the Pallas kernel."""
    h = matmul_bias(x, w1, b1, relu=True)
    out = matmul_bias(h, w2, b2, relu=False)
    return (out,)
