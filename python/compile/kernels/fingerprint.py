"""L1 Pallas kernel: batched xxHash32-style message fingerprints.

uBFT's registers and checkpoint/summary machinery fingerprint messages
constantly. The per-message path in Rust uses native xxhash; the *bulk*
verification of a CTBcast tail (checkpoint/summary time, a background
task in the paper) is expressed here as a Pallas kernel so it lowers into
the same AOT HLO module the Rust coordinator executes via PJRT.

Bit-compatibility contract: this kernel must equal
``ubft::crypto::lane_fingerprint32`` in Rust (one xxHash32 round per u32
word, seed lane ``seed + PRIME5``, length mix, standard avalanche). The
pytest suite pins the pure-python reference; ``it_runtime.rs``
cross-checks Rust-native vs the compiled HLO.

TPU mapping (DESIGN.md §Hardware-Adaptation): the (B, W) message matrix is
tiled along B via ``BlockSpec``; each block streams HBM→VMEM once and does
pure VPU integer work (no MXU). W is a compile-time constant so the word
loop fully unrolls into vector ops.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PRIME32_1 = np.uint32(0x9E3779B1)
PRIME32_2 = np.uint32(0x85EBCA77)
PRIME32_3 = np.uint32(0xC2B2AE3D)
PRIME32_5 = np.uint32(0x165667B1)


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def _round(acc, w):
    return _rotl(acc + w * PRIME32_2, 13) * PRIME32_1


def _avalanche(acc):
    acc = acc ^ (acc >> np.uint32(15))
    acc = acc * PRIME32_2
    acc = acc ^ (acc >> np.uint32(13))
    acc = acc * PRIME32_3
    acc = acc ^ (acc >> np.uint32(16))
    return acc


def _fingerprint_block(x, seed):
    """Fingerprint each row of a (b, W) uint32 block."""
    words = x.shape[1]
    acc = jnp.full((x.shape[0],), np.uint32((seed + 0x165667B1) & 0xFFFFFFFF), dtype=jnp.uint32)
    for i in range(words):  # unrolled: W is static
        acc = _round(acc, x[:, i])
    acc = acc + np.uint32((words * 4) & 0xFFFFFFFF)
    return _avalanche(acc)


def _kernel(x_ref, o_ref, *, seed):
    o_ref[...] = _fingerprint_block(x_ref[...], seed)


@functools.partial(jax.jit, static_argnames=("block_b", "seed"))
def fingerprint(x, block_b=32, seed=0):
    """Fingerprint a batch of messages.

    Args:
      x: (B, W) uint32 — zero-padded little-endian message words.
      block_b: rows per grid step (VMEM tile height).
      seed: xxHash seed lane.

    Returns:
      (B,) uint32 fingerprints.
    """
    b, w = x.shape
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, seed=seed),
        grid=((b + pad) // bb,),
        in_specs=[pl.BlockSpec((bb, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b + pad,), jnp.uint32),
        interpret=True,  # CPU path; real-TPU lowering is compile-only here
    )(x)
    return out[:b]
