"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""

import jax.numpy as jnp

PRIME32_1 = jnp.uint32(0x9E3779B1)
PRIME32_2 = jnp.uint32(0x85EBCA77)
PRIME32_3 = jnp.uint32(0xC2B2AE3D)
PRIME32_5 = jnp.uint32(0x165667B1)


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def ref_fingerprint(x, seed=0):
    """Reference for kernels.fingerprint: (B, W) uint32 -> (B,) uint32."""
    x = jnp.asarray(x, dtype=jnp.uint32)
    words = x.shape[1]
    acc = jnp.full((x.shape[0],), jnp.uint32(seed) + PRIME32_5, dtype=jnp.uint32)
    for i in range(words):
        acc = _rotl(acc + x[:, i] * PRIME32_2, 13) * PRIME32_1
    acc = acc + jnp.uint32(words * 4)
    acc = acc ^ (acc >> jnp.uint32(15))
    acc = acc * PRIME32_2
    acc = acc ^ (acc >> jnp.uint32(13))
    acc = acc * PRIME32_3
    acc = acc ^ (acc >> jnp.uint32(16))
    return acc


def py_fingerprint(words, seed=0):
    """Plain-int mirror of ``ubft::crypto::lane_fingerprint32`` (the Rust
    implementation), used to pin cross-language bit-compatibility."""
    mask = 0xFFFFFFFF
    p1, p2, p3, p5 = 0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x165667B1
    acc = (seed + p5) & mask
    for w in words:
        acc = (acc + w * p2) & mask
        acc = ((acc << 13) | (acc >> 19)) & mask
        acc = (acc * p1) & mask
    acc = (acc + len(words) * 4) & mask
    acc ^= acc >> 15
    acc = (acc * p2) & mask
    acc ^= acc >> 13
    acc = (acc * p3) & mask
    acc ^= acc >> 16
    return acc


def ref_matmul_bias(x, w, b, relu=False):
    """Reference for kernels.matmul: act(x @ w + b)."""
    out = jnp.dot(x, w) + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def ref_mlp(x, w1, b1, w2, b2):
    """Reference two-layer MLP forward."""
    h = ref_matmul_bias(x, w1, b1, relu=True)
    return ref_matmul_bias(h, w2, b2, relu=False)
