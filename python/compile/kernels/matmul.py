"""L1 Pallas kernel: tiled matmul + bias + optional ReLU.

Backs the BFT-replicated tensor service (``apps::TensorApp``): the MLP
forward pass (L2, ``model.py``) calls this kernel for both layers so the
whole network lowers into one AOT HLO module.

TPU mapping: classic (bm, bn) output tiling with the full K panel resident
— for the service's layer sizes (≤ 32×32) one K panel fits VMEM easily; at
MXU scale bm=bn=128 with a K loop would be the shape (DESIGN.md §8).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    acc = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "relu"))
def matmul_bias(x, w, b, block_m=8, block_n=32, relu=False):
    """Compute ``act(x @ w + b)`` with a Pallas grid over output tiles.

    Args:
      x: (M, K) f32.
      w: (K, N) f32.
      b: (N,) f32.
      relu: apply ReLU when True.

    Returns:
      (M, N) f32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = min(block_m, m)
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_n)))
        b = jnp.pad(b, (0, pad_n))
    mm, nn = m + pad_m, n + pad_n
    out = pl.pallas_call(
        functools.partial(_kernel, relu=relu),
        grid=(mm // bm, nn // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=True,  # CPU path; see fingerprint.py
    )(x, w, b)
    return out[:m, :n]
