"""AOT compile path: lower the L2 functions to HLO *text* artifacts that
the Rust runtime loads via the PJRT CPU client.

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_module().serialize()``:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Shapes here are the single source of truth and must match
``rust/src/runtime/mod.rs::shapes``.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed export shapes (mirrored in rust/src/runtime/mod.rs::shapes).
FP_BATCH, FP_WORDS = 64, 16
MLP_BATCH, MLP_IN, MLP_HIDDEN, MLP_OUT = 8, 16, 32, 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def exports():
    u32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.uint32)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return {
        "fingerprint": (model.fingerprint_batch, [u32(FP_BATCH, FP_WORDS)]),
        "batch_verify": (model.batch_verify, [u32(FP_BATCH, FP_WORDS), u32(FP_BATCH)]),
        "mlp": (
            model.mlp_forward,
            [
                f32(MLP_BATCH, MLP_IN),
                f32(MLP_IN, MLP_HIDDEN),
                f32(MLP_HIDDEN),
                f32(MLP_HIDDEN, MLP_OUT),
                f32(MLP_OUT),
            ],
        ),
    }


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, (fn, specs) in exports().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
