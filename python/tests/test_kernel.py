"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, seeds and block sizes; plain tests pin the
cross-language contract with the Rust implementation.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fingerprint import fingerprint
from compile.kernels.matmul import matmul_bias
from compile.kernels.ref import py_fingerprint, ref_fingerprint, ref_matmul_bias


# ---------------------------------------------------------------------
# fingerprint kernel
# ---------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=96),
    w=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    block=st.sampled_from([1, 4, 8, 32]),
    data=st.data(),
)
def test_fingerprint_matches_ref(b, w, seed, block, data):
    raw = data.draw(
        st.lists(
            st.integers(min_value=0, max_value=2**32 - 1),
            min_size=b * w,
            max_size=b * w,
        )
    )
    x = np.array(raw, dtype=np.uint32).reshape(b, w)
    got = np.asarray(fingerprint(x, block_b=block, seed=seed))
    want = np.asarray(ref_fingerprint(x, seed=seed))
    np.testing.assert_array_equal(got, want)


def test_fingerprint_matches_rust_contract():
    # py_fingerprint mirrors ubft::crypto::lane_fingerprint32 word-for-word;
    # the kernel must agree on every row.
    rng = np.random.default_rng(7)
    x = rng.integers(0, 2**32, size=(16, 16), dtype=np.uint32)
    got = np.asarray(fingerprint(x))
    for i in range(16):
        assert got[i] == py_fingerprint([int(v) for v in x[i]]), f"row {i}"


def test_fingerprint_known_answer_zero_row():
    # One pinned value so any constant/rotation regression is caught
    # even if kernel and oracle drift together.
    x = np.zeros((1, 4), dtype=np.uint32)
    expected = py_fingerprint([0, 0, 0, 0])
    assert int(np.asarray(fingerprint(x))[0]) == expected


def test_fingerprint_distinct_rows_distinct_outputs():
    x = np.arange(64 * 16, dtype=np.uint32).reshape(64, 16)
    fps = np.asarray(fingerprint(x))
    assert len(set(fps.tolist())) == 64


def test_fingerprint_seed_sensitivity():
    x = np.ones((4, 8), dtype=np.uint32)
    a = np.asarray(fingerprint(x, seed=0))
    b = np.asarray(fingerprint(x, seed=1))
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=24),
    k=st.integers(min_value=1, max_value=24),
    n=st.integers(min_value=1, max_value=40),
    relu=st.booleans(),
    bm=st.sampled_from([1, 4, 8]),
    bn=st.sampled_from([4, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matmul_matches_ref(m, k, n, relu, bm, bn, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k), dtype=np.float32)
    w = rng.standard_normal((k, n), dtype=np.float32)
    b = rng.standard_normal(n, dtype=np.float32)
    got = np.asarray(matmul_bias(x, w, b, block_m=bm, block_n=bn, relu=relu))
    want = np.asarray(ref_matmul_bias(x, w, b, relu=relu))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_relu_clamps_negatives():
    x = np.array([[1.0, -1.0]], dtype=np.float32)
    w = np.eye(2, dtype=np.float32)
    b = np.zeros(2, dtype=np.float32)
    out = np.asarray(matmul_bias(x, w, b, relu=True))
    np.testing.assert_array_equal(out, [[1.0, 0.0]])


def test_matmul_bias_applied():
    x = np.zeros((2, 3), dtype=np.float32)
    w = np.zeros((3, 4), dtype=np.float32)
    b = np.arange(4, dtype=np.float32)
    out = np.asarray(matmul_bias(x, w, b))
    np.testing.assert_array_equal(out, np.tile(b, (2, 1)))


def test_matmul_rejects_shape_mismatch():
    x = np.zeros((2, 3), dtype=np.float32)
    w = np.zeros((4, 4), dtype=np.float32)
    b = np.zeros(4, dtype=np.float32)
    with pytest.raises(AssertionError):
        matmul_bias(x, w, b)
