"""L2 model graphs vs references, plus AOT export sanity."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.aot import FP_BATCH, FP_WORDS, MLP_BATCH, MLP_HIDDEN, MLP_IN, MLP_OUT, exports, to_hlo_text
from compile.kernels.ref import ref_fingerprint, ref_mlp

import jax


def test_batch_verify_flags_matches_and_mismatches():
    rng = np.random.default_rng(3)
    msgs = rng.integers(0, 2**32, size=(8, 4), dtype=np.uint32)
    expected = np.asarray(ref_fingerprint(msgs)).copy()
    expected[3] ^= 1  # corrupt one digest
    (mask,) = model.batch_verify(msgs, expected)
    mask = np.asarray(mask)
    want = np.ones(8, dtype=np.uint32)
    want[3] = 0
    np.testing.assert_array_equal(mask, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_mlp_forward_matches_ref(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((MLP_BATCH, MLP_IN), dtype=np.float32)
    w1 = rng.standard_normal((MLP_IN, MLP_HIDDEN), dtype=np.float32)
    b1 = rng.standard_normal(MLP_HIDDEN, dtype=np.float32)
    w2 = rng.standard_normal((MLP_HIDDEN, MLP_OUT), dtype=np.float32)
    b2 = rng.standard_normal(MLP_OUT, dtype=np.float32)
    (got,) = model.mlp_forward(x, w1, b1, w2, b2)
    want = ref_mlp(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fingerprint_batch_shape():
    msgs = np.zeros((FP_BATCH, FP_WORDS), dtype=np.uint32)
    (fps,) = model.fingerprint_batch(msgs)
    assert fps.shape == (FP_BATCH,)
    assert fps.dtype == np.uint32


def test_all_exports_lower_to_hlo_text():
    for name, (fn, specs) in exports().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        assert "HloModule" in text, name
        assert len(text) > 200, name
